"""Equivalence tests for the §Perf hillclimb knobs: every optimized path must
match its baseline numerically (the 'debug forward, keep the speedup' gate)."""

import dataclasses
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
ENV = dict(os.environ, PYTHONPATH=os.path.join(REPO, "src"))


def test_moe_a2a_matches_scatter_8dev():
    script = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import dataclasses, jax, jax.numpy as jnp, numpy as np
        from repro.models import ModelConfig, LayerSpec, MoEConfig, moe, common
        mesh = jax.make_mesh((2, 4), ('data', 'model'))
        cfg = ModelConfig(name='t', n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
                          d_ff=128, vocab=128, pattern=(LayerSpec(ffn='moe'),),
                          moe=MoEConfig(num_experts=8, top_k=2, d_ff=32, capacity_factor=8.0),
                          act_dtype='float32')
        params = jax.tree.map(lambda x: x.astype(jnp.float32),
                              common.init_params(moe.defs(cfg), jax.random.PRNGKey(0)))
        x = jax.random.normal(jax.random.PRNGKey(1), (128, 64), jnp.float32)
        y_sc, _ = jax.jit(lambda p, xx: moe.apply_scatter(p, xx, cfg, mesh))(params, x)
        cfg2 = dataclasses.replace(cfg, moe=dataclasses.replace(cfg.moe, impl='shard_map_a2a'))
        y_a2a, _ = jax.jit(lambda p, xx: moe.apply(p, xx, cfg2, mesh))(params, x)
        assert float(jnp.max(jnp.abs(y_sc - y_a2a))) == 0.0
        # And gradients flow identically through the router.
        def loss(p, impl_cfg):
            y, _ = moe.apply(p, x, impl_cfg, mesh)
            return jnp.sum(y ** 2)
        g1 = jax.grad(loss)(params, cfg)
        g2 = jax.grad(loss)(params, cfg2)
        for a, b in zip(jax.tree.leaves(g1), jax.tree.leaves(g2)):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=2e-5, atol=2e-5)
        print('MOE-A2A-OK')
    """)
    r = subprocess.run([sys.executable, "-c", script], env=ENV, capture_output=True, text=True, timeout=900)
    assert "MOE-A2A-OK" in r.stdout, (r.stdout[-400:], r.stderr[-2500:])


def test_sharded_xent_matches_gather():
    from repro.models import common

    rng = np.random.default_rng(0)
    logits = jnp.asarray(rng.normal(size=(2, 8, 64)).astype(np.float32))
    targets = jnp.asarray(rng.integers(0, 64, (2, 8)), jnp.int32)
    a = common.softmax_xent(logits, targets)
    b = common.softmax_xent_sharded(logits, targets, mesh=None)
    assert abs(float(a) - float(b)) < 1e-6
    mask = jnp.asarray(rng.integers(0, 2, (2, 8)) > 0)
    a = common.softmax_xent(logits, targets, mask)
    b = common.softmax_xent_sharded(logits, targets, None, mask)
    assert abs(float(a) - float(b)) < 1e-6


@pytest.mark.parametrize("chunk,intra", [(8, "float32"), (4, "float32"), (8, "bfloat16")])
def test_ssd_chunk_and_dtype_variants(chunk, intra):
    """Chunk size must not change results (exact algebra); bf16 intra stays
    within bf16 tolerance of the f32 reference."""
    from repro.models import LayerSpec, ModelConfig, SSMConfig, common, ssm

    def build(chunk_, intra_):
        return ModelConfig(
            name="s", n_layers=1, d_model=32, n_heads=4, n_kv_heads=4, d_ff=0,
            vocab=64, pattern=(LayerSpec(mixer="mamba", ffn="none"),),
            ssm=SSMConfig(d_state=8, head_dim=8, chunk=chunk_, intra_dtype=intra_),
            act_dtype="float32",
        )

    ref_cfg = build(16, "float32")  # single chunk (seq=16)
    cfg = build(chunk, intra)
    params = jax.tree.map(
        lambda x: x.astype(jnp.float32),
        common.init_params(ssm.defs(ref_cfg), jax.random.PRNGKey(3)),
    )
    x = jax.random.normal(jax.random.PRNGKey(4), (2, 16, 32), jnp.float32) * 0.5
    y_ref = ssm.apply(params, x, ref_cfg)
    y = ssm.apply(params, x, cfg)
    tol = 1e-5 if intra == "float32" else 3e-2
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref), rtol=tol, atol=tol)


def test_remat_policies_same_loss():
    from repro import configs
    from repro.models import common, transformer

    cfg = configs.smoke_config("qwen3-8b")
    params = common.init_params(transformer.model_defs(cfg), jax.random.PRNGKey(5))
    batch = {
        "tokens": jnp.zeros((2, 16), jnp.int32),
        "targets": jnp.ones((2, 16), jnp.int32),
    }
    losses = []
    for remat in [True, "dots", False]:
        l, _ = transformer.loss_fn(params, batch, cfg, remat=remat)
        losses.append(float(l))
    assert max(losses) - min(losses) < 1e-5, losses


def test_microbatch_grads_match_full_batch():
    from repro import configs
    from repro.models import common, transformer
    from repro.train import optimizer, train_step as ts

    cfg = configs.smoke_config("h2o-danube-1.8b")
    params = common.init_params(transformer.model_defs(cfg), jax.random.PRNGKey(6))
    params = jax.tree.map(lambda x: x.astype(jnp.float32), params)
    rng = np.random.default_rng(1)
    batch = {
        "tokens": jnp.asarray(rng.integers(0, cfg.vocab, (4, 16)), jnp.int32),
        "targets": jnp.asarray(rng.integers(0, cfg.vocab, (4, 16)), jnp.int32),
    }
    ocfg = optimizer.OptConfig(lr=0.0, weight_decay=0.0, warmup_steps=0)

    outs = []
    for mb in (1, 2):
        step = ts.make_train_step(cfg, ocfg, None, microbatches=mb)
        opt, comp, sk = ts.init_states(cfg, ocfg, params)
        _, _, _, _, metrics = step(params, opt, comp, sk, batch)
        outs.append(float(metrics["loss"]))
    # Same mean loss across microbatch splits (grads averaged identically).
    assert abs(outs[0] - outs[1]) < 1e-4, outs


def test_padded_heads_equivalence():
    """Padded-head attention (llava/whisper/arctic shapes) must equal the
    unpadded computation on the real heads, with the ORIGINAL GQA wiring."""
    from repro.models import LayerSpec, ModelConfig, attention, common

    # GQA case: 56 q / 8 kv -> padded 64 q / 8 kv, g 7 -> 8 (interleaved).
    cfg = ModelConfig(name="p", n_layers=1, d_model=64, n_heads=56, n_kv_heads=8,
                      d_ff=0, vocab=64, d_head=4, act_dtype="float32")
    d = attention.defs(cfg)
    assert d["wq"].shape == (64, 64, 4)
    assert d["wk"].shape == (64, 8, 4)
    params = jax.tree.map(lambda x: x.astype(jnp.float32),
                          common.init_params(d, jax.random.PRNGKey(0)))
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 8, 64), jnp.float32)
    y_pad, _ = attention.apply(params, x, cfg, LayerSpec(), positions=jnp.arange(8))

    # Reference: extract the real-head slots (slot j < 7 within each kv group
    # of 8) and compute without padding machinery.
    real_idx = np.array([k * 8 + j for k in range(8) for j in range(7)])
    p_ref = {"wq": params["wq"][:, real_idx], "wk": params["wk"], "wv": params["wv"],
             "wo": params["wo"][real_idx]}
    sin, cos = common.rope_tables(jnp.arange(8), cfg.head_dim, cfg.rope_theta)
    q = common.apply_rope(jnp.einsum("bse,ehd->bshd", x, p_ref["wq"]), sin, cos)
    k = common.apply_rope(jnp.einsum("bte,ehd->bthd", x, p_ref["wk"]), sin, cos)
    v = jnp.einsum("bte,ehd->bthd", x, p_ref["wv"])
    out = attention.chunked_attention(q, k, v, causal=True, window=None)
    y_ref = jnp.einsum("bshd,hde->bse", out, p_ref["wo"])
    np.testing.assert_allclose(np.asarray(y_pad), np.asarray(y_ref), rtol=1e-5, atol=1e-5)

    # MHA case: 20/20 -> 32/32, real iff head < 20.
    cfg2 = ModelConfig(name="p2", n_layers=1, d_model=80, n_heads=20, n_kv_heads=20,
                       d_ff=0, vocab=64, d_head=4, act_dtype="float32")
    d2 = attention.defs(cfg2)
    assert d2["wq"].shape == (80, 32, 4) and d2["wk"].shape == (80, 32, 4)
    params2 = jax.tree.map(lambda x: x.astype(jnp.float32),
                           common.init_params(d2, jax.random.PRNGKey(2)))
    x2 = jax.random.normal(jax.random.PRNGKey(3), (2, 8, 80), jnp.float32)
    y2, _ = attention.apply(params2, x2, cfg2, LayerSpec(), positions=jnp.arange(8))
    p2_ref = {"wq": params2["wq"][:, :20], "wk": params2["wk"][:, :20],
              "wv": params2["wv"][:, :20], "wo": params2["wo"][:20]}
    sin, cos = common.rope_tables(jnp.arange(8), cfg2.head_dim, cfg2.rope_theta)
    q = common.apply_rope(jnp.einsum("bse,ehd->bshd", x2, p2_ref["wq"]), sin, cos)
    k = common.apply_rope(jnp.einsum("bte,ehd->bthd", x2, p2_ref["wk"]), sin, cos)
    v = jnp.einsum("bte,ehd->bthd", x2, p2_ref["wv"])
    out = attention.chunked_attention(q, k, v, causal=True, window=None)
    y2_ref = jnp.einsum("bshd,hde->bse", out, p2_ref["wo"])
    np.testing.assert_allclose(np.asarray(y2), np.asarray(y2_ref), rtol=1e-5, atol=1e-5)
