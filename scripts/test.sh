#!/usr/bin/env bash
# Tier-1 test entry: one command, correct env.
#
#   scripts/test.sh                 # full tier-1 suite
#   scripts/test.sh --tier2         # tier-1 + benchmark smoke paths
#   scripts/test.sh tests/test_kernels.py -k qsketch   # pass-through args
#
# - PYTHONPATH=src so `repro` imports without an install step.
# - XLA_FLAGS exposes 8 host devices (per SNIPPETS.md) so mesh/sharding tests
#   exercise multi-device code paths on a CPU-only box; an existing
#   XLA_FLAGS setting is preserved and extended.
# - --tier2 additionally (1) audits public docstrings in core/ +
#   sketchstream/ + kernels/ (scripts/check_docstrings.py — the shape/dtype
#   and merge contracts live there), (2) enforces the estimation layering:
#   containers and monitors must solve histograms through core/estimation.py
#   (DESIGN.md §8.7), never by calling estimators.qsketch_mle themselves —
#   a direct call would bypass the solver registry, the routed ×m scaling,
#   and the untouched-row guard, (3) runs `python -m benchmarks.run --smoke`
#   (the quick profile over the fast suites, incl. the sharded SketchArray /
#   DynArray / WindowArray sweeps and the estimation solver sweep) so CI
#   catches benchmark-path rot without paying for the paper-scale sweeps,
#   then (4) asserts the cumulative bench-JSON schema (required keys,
#   unique + monotone K per group) so a broken cumulative merge fails
#   loudly instead of silently dropping or duplicating rows.
set -euo pipefail
cd "$(dirname "$0")/.."

export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"
export XLA_FLAGS="--xla_force_host_platform_device_count=8${XLA_FLAGS:+ $XLA_FLAGS}"

tier2=0
if [[ "${1:-}" == "--tier2" ]]; then
  tier2=1
  shift
fi

python -m pytest -x -q "$@"

if [[ "$tier2" == 1 ]]; then
  echo "== tier-2: public docstring audit =="
  python scripts/check_docstrings.py
  echo "== tier-2: estimation layering check =="
  # Only the estimation layer may call the raw Newton solver; everything
  # else goes through estimation.estimate_* (solver registry + guards).
  if grep -rn "qsketch_mle" src/repro/core src/repro/sketchstream \
      --include='*.py' \
      --exclude=estimation.py --exclude=estimators.py; then
    echo "FAIL: call estimators.qsketch_mle only via core/estimation.py" >&2
    exit 1
  fi
  echo "layering: OK"
  echo "== tier-2: benchmark smoke paths =="
  python -m benchmarks.run --smoke
  echo "== tier-2: bench JSON schema =="
  python scripts/check_bench_schema.py
fi
