"""Sparse tenant-key directory: 64-bit tenant ids -> SketchArray slots.

Production streams do not carry dense keys in [0, K): they carry sparse
64-bit tenant ids (user ids, flow 5-tuples hashes, org ids) drawn from a
space of 2^64. This module is the layer between those ids and the dense row
index a ``SketchArray`` / ``ShardedSketchArray`` wants, replacing the
dense-int key contract PR 1 baked into every update entry point.

Routing is *stateless*: slot(x) is a pure function of the tenant id and a
frozen ``DirectoryConfig`` (the same murmur-style family as every other hash
role, ``core/hashing.py``), so two hosts route the same tenant identically
and the sharded max-monoid merge stays exact. Two refinements on top of the
plain hash:

* **Pinned hot keys.** ``DirectoryConfig.pinned`` is a small static tuple of
  tenant ids with *dedicated* slots [0, len(pinned)): a pinned tenant can
  never collide and never be collided with (hashed tenants land in
  [num_pinned, capacity)). This is the classic elephant-flow table: the few
  tenants you bill/alert on get exact rows, the long tail shares.
* **Collision telemetry.** Hash routing aliases tenants at the birthday
  rate; aliasing inflates the aliased rows' estimates (union of two
  tenants' streams — still an exact QSketch of that union, per Wang et
  al.'s shared-register analysis in PAPERS.md). ``route`` keeps a per-slot
  32-bit fingerprint of the first claiming tenant and counts routings whose
  fingerprint mismatches, so operators can watch the actual collision rate
  and grow ``capacity`` when it drifts.

Telemetry approximations (documented contract):
  * first-contact claims within ONE batch are resolved by max-fingerprint
    and not counted as collisions until the next batch that revisits the
    slot (scatter sees the pre-batch claim table);
  * a fingerprint match is necessary but not sufficient for identity
    (32-bit: false-negative rate 2^-32 per routing) — counters are
    telemetry, never correctness.

Cold-fingerprint aging (the ROADMAP follow-on): long-lived directories
accumulate claims from tenants that stopped sending traffic, so the
collision counters drift up against ghosts. ``route`` stamps every routed
slot with the caller's ``epoch`` (any monotone clock — the natural one is
``WindowArrayState.epoch_id``, advanced by each window rotation), and
``evict_older_than(dcfg, state, epoch)`` releases hashed slots whose last
touch predates ``epoch``: the fingerprint claim is cleared so the next
tenant to land there claims it fresh instead of counting a collision.
Aging is telemetry-only, like the counters themselves — sketch rows are NOT
cleared (the sketch layer owns its own eviction; the window array's ring
rotation ages register state out on the same clock). Pinned slots never age.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from . import hashing


@dataclasses.dataclass(frozen=True)
class DirectoryConfig:
    """Frozen (hashable) routing config — a valid ``jax.jit`` static arg.

    Attributes:
      capacity: total slot count K (== the SketchArray row count it fronts).
      seed: base salt; routing and fingerprint roles derive sub-salts.
      pinned: static tuple of 64-bit tenant ids with dedicated slots
        [0, len(pinned)); everyone else hashes into [len(pinned), capacity).
    """

    capacity: int
    seed: int = 0x5EED
    pinned: tuple = ()

    def __post_init__(self):
        if self.capacity < 1:
            raise ValueError("directory capacity must be >= 1")
        if len(self.pinned) >= self.capacity:
            raise ValueError("pinned table must leave at least one hashed slot")
        if len(set(self.pinned)) != len(self.pinned):
            raise ValueError("pinned tenant ids must be distinct")
        for t in self.pinned:
            if not 0 <= int(t) < 2**64:
                raise ValueError(f"pinned tenant id out of 64-bit range: {t}")

    @property
    def num_pinned(self) -> int:
        """Dedicated hot-tenant slots [0, num_pinned)."""
        return len(self.pinned)

    @property
    def num_hashed(self) -> int:
        """Shared hashed slots [num_pinned, capacity)."""
        return self.capacity - self.num_pinned

    @property
    def salt_route(self) -> int:
        """Derived salt of the tenant -> slot routing hash role."""
        return (self.seed * 0x9E3779B1 + 11) & 0xFFFFFFFF

    @property
    def salt_fp(self) -> int:
        """Derived salt of the per-slot claim-fingerprint hash role."""
        return (self.seed * 0x9E3779B1 + 12) & 0xFFFFFFFF


def pin(dcfg: DirectoryConfig, tenant, *, grow: bool = False) -> DirectoryConfig:
    """Pin a tenant into the dedicated hot table: -> a NEW DirectoryConfig.

    WARNING — pinning RE-KEYS every hashed tenant. The hashed range is
    [num_pinned, capacity): appending to ``pinned`` shifts its base by one
    and (unless ``grow=True``) shrinks ``num_hashed`` by one, so
    ``route_slots`` moves essentially EVERY unpinned tenant to a different
    slot. Dense containers routed by this directory (SketchArray / DynArray
    / WindowArray rows) keep their old rows' register state, which the new
    mapping no longer points at — estimates read other tenants' residue.
    Callers pinning a live dense directory must therefore either:

      * epoch-fence: re-init the sketch rows and the ``DirectoryState``
        (fingerprint claims are per-slot and equally stale) and let history
        age out — the window array's rotation clock is the natural fence; or
      * rebuild: replay/merge old rows into their new slots host-side.

    The virtual tier is immune to this footgun: ``VirtualDynArray`` pool
    placement hashes (tenant, register) directly and never sees the pinned
    set, which is why ``virtual_dyn_array.promote`` re-keys nobody and can
    offer migration semantics (its docstring). This helper exists so dense
    callers get the same one-call ergonomics WITH the contract spelled out.

    grow=False (default) keeps ``capacity`` (the sketch row count) fixed —
    the new hot slot is carved out of the hashed range. ``grow=True`` adds a
    row (capacity + 1), preserving ``num_hashed``; the caller must grow the
    fronted container by one row to match.
    """
    t = int(tenant)
    if not 0 <= t < 2**64:
        raise ValueError(f"tenant id out of 64-bit range: {tenant}")
    if t in tuple(int(x) for x in dcfg.pinned):
        raise ValueError(f"tenant {tenant} is already pinned")
    return dataclasses.replace(
        dcfg,
        pinned=dcfg.pinned + (t,),
        capacity=dcfg.capacity + (1 if grow else 0),
    )


class DirectoryState(NamedTuple):
    """Collision-telemetry state (routing itself is stateless).

    fingerprints: uint32[capacity]; 0 = slot never claimed, else the (nonzero)
      fingerprint of the first tenant observed on that slot.
    n_routed: int32 — live elements routed so far (occurrences).
    n_collisions: int32 — routings whose slot fingerprint mismatched (i.e.
      traffic landing on a row already owned by a different tenant).
    last_touch: int32[capacity] — the caller-supplied epoch of the last live
      OWNER routing to each slot (-1 = never touched; colliding routings do
      not stamp); the aging clock.

    Schema note: ``last_touch`` was added after the first directory release;
    checkpoints written with the older 3-field state do not restore into
    this one (telemetry state is versioned with the code, like every other
    state schema in this repo — re-init monitors on upgrade).
    """

    fingerprints: jnp.ndarray
    n_routed: jnp.ndarray
    n_collisions: jnp.ndarray
    last_touch: jnp.ndarray


def init(dcfg: DirectoryConfig) -> DirectoryState:
    """Empty telemetry: no claims (fingerprint 0), zero counters, stamps -1."""
    return DirectoryState(
        fingerprints=jnp.zeros((dcfg.capacity,), jnp.uint32),
        n_routed=jnp.int32(0),
        n_collisions=jnp.int32(0),
        last_touch=jnp.full((dcfg.capacity,), -1, jnp.int32),
    )


def split_uint64(ids) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Host-side helper: numpy uint64/int tenant ids -> (lo, hi) uint32 pair.

    JAX x64 is off by default, so 64-bit ids cross the host boundary as two
    uint32 words (the same convention as ``hashing.split_id64``).
    """
    ids = np.asarray(ids, dtype=np.uint64)
    lo = (ids & np.uint64(0xFFFFFFFF)).astype(np.uint32)
    hi = (ids >> np.uint64(32)).astype(np.uint32)
    return jnp.asarray(lo), jnp.asarray(hi)


def _fingerprint(dcfg: DirectoryConfig, lo, hi):
    """Nonzero uint32 tenant fingerprint (0 is the unclaimed sentinel)."""
    fp = hashing.hash_words((lo, hi), dcfg.salt_fp)
    return jnp.where(fp == 0, jnp.uint32(1), fp)


def route_slots(dcfg: DirectoryConfig, keys) -> jnp.ndarray:
    """Stateless tenant -> slot map, int32[B] in [0, capacity).

    ``keys`` is a uint32/int32 array (hi word 0) or a (lo, hi) uint32 pair.
    Hashed tenants land in [num_pinned, capacity) via the unbiased
    multiply-shift of ``hashing.hash_mod``; pinned tenants override to their
    dedicated slot. Pure function of (dcfg, keys): identical on every host.
    """
    lo, hi = hashing.split_id64(keys)
    slots = dcfg.num_pinned + hashing.hash_mod((lo, hi), dcfg.salt_route, dcfg.num_hashed)
    for i, t in enumerate(dcfg.pinned):
        t = int(t)
        t_lo, t_hi = jnp.uint32(t & 0xFFFFFFFF), jnp.uint32(t >> 32)
        slots = jnp.where((lo == t_lo) & (hi == t_hi), jnp.int32(i), slots)
    return slots


@functools.partial(jax.jit, static_argnums=(0,))
def route(dcfg: DirectoryConfig, state: DirectoryState, keys, mask=None, epoch=None):
    """Route a batch AND update collision telemetry: -> (slots, state').

    Masked-off rows get a valid slot (callers pair them with the same mask
    downstream) but touch neither the claim table nor the counters.

    ``epoch`` (int32 scalar, any monotone clock — e.g. the window array's
    ``epoch_id``) stamps each live slot's ``last_touch`` via scatter-max, the
    input to ``evict_older_than``. Omitted, routings stamp epoch 0 (a
    directory that never ages sees one eternal epoch).
    """
    lo, hi = hashing.split_id64(keys)
    slots = route_slots(dcfg, (lo, hi))
    fp = _fingerprint(dcfg, lo, hi)
    live = jnp.ones(lo.shape, bool) if mask is None else mask
    epoch = jnp.int32(0) if epoch is None else jnp.asarray(epoch, jnp.int32)

    cur = state.fingerprints[slots]
    collided = live & (cur != 0) & (cur != fp)
    # First-writer claim as a scatter-max: claimed slots contribute 0 (the
    # existing nonzero fingerprint wins); contested fresh slots resolve to the
    # max fingerprint — deterministic under any scatter order.
    claim = jnp.where(live & (cur == 0), fp, jnp.uint32(0))
    fps = state.fingerprints.at[slots].max(claim)
    # Only owner/claim traffic keeps a slot warm: a COLLIDING routing must
    # not re-stamp the ghost fingerprint it collided with, or a departed
    # tenant's slot under active colliding traffic would never age out —
    # the exact drift aging exists to stop. (-1 never beats a stamp.)
    touch = jnp.where(live & ~collided, epoch, jnp.int32(-1))
    return slots, DirectoryState(
        fingerprints=fps,
        n_routed=state.n_routed + jnp.sum(live).astype(jnp.int32),
        n_collisions=state.n_collisions + jnp.sum(collided).astype(jnp.int32),
        last_touch=state.last_touch.at[slots].max(touch),
    )


@functools.partial(jax.jit, static_argnums=(0,))
def evict_older_than(dcfg: DirectoryConfig, state: DirectoryState, epoch):
    """Release hashed slots whose last live routing predates ``epoch``:
    -> (state', n_evicted int32).

    A released slot drops its fingerprint claim (and its stamp resets to -1),
    so the next tenant routed there claims it first-contact instead of
    counting a collision against a ghost. Pinned slots [0, num_pinned) are
    exempt — they are dedicated by construction. Cumulative counters are
    untouched: eviction changes who owns a slot, not what already happened.
    """
    epoch = jnp.asarray(epoch, jnp.int32)
    slot_ids = jnp.arange(dcfg.capacity, dtype=jnp.int32)
    cold = (
        (slot_ids >= dcfg.num_pinned)
        & (state.fingerprints != 0)
        & (state.last_touch < epoch)
    )
    return (
        DirectoryState(
            fingerprints=jnp.where(cold, jnp.uint32(0), state.fingerprints),
            n_routed=state.n_routed,
            n_collisions=state.n_collisions,
            last_touch=jnp.where(cold, jnp.int32(-1), state.last_touch),
        ),
        jnp.sum(cold).astype(jnp.int32),
    )


def merge(a: DirectoryState, b: DirectoryState) -> DirectoryState:
    """Cross-host telemetry merge.

    Claims resolve by max fingerprint (same rule as in-batch contention);
    slots claimed by *different* tenants on the two hosts are surfaced as one
    collision each — the cross-host analogue of a mismatched routing.
    """
    if a.fingerprints.shape != b.fingerprints.shape:
        raise ValueError(
            "directory merge needs equal capacities, got "
            f"{a.fingerprints.shape} vs {b.fingerprints.shape}"
        )
    cross = jnp.sum((a.fingerprints != 0) & (b.fingerprints != 0) & (a.fingerprints != b.fingerprints))
    return DirectoryState(
        fingerprints=jnp.maximum(a.fingerprints, b.fingerprints),
        n_routed=a.n_routed + b.n_routed,
        n_collisions=a.n_collisions + b.n_collisions + cross.astype(jnp.int32),
        last_touch=jnp.maximum(a.last_touch, b.last_touch),
    )


def occupancy(state: DirectoryState) -> jnp.ndarray:
    """Fraction of slots ever claimed (f32 scalar)."""
    claimed = jnp.sum((state.fingerprints != 0).astype(jnp.float32))
    return claimed / state.fingerprints.shape[0]


def collision_rate(state: DirectoryState) -> jnp.ndarray:
    """Collided routings / total routings (f32 scalar; 0 for an empty dir)."""
    n = jnp.maximum(state.n_routed.astype(jnp.float32), 1.0)
    return state.n_collisions.astype(jnp.float32) / n
