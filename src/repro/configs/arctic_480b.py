"""arctic-480b [moe] — 128 experts top-2 PLUS a dense residual path.

35L d_model=7168 56H (GQA kv=8) d_ff=4864 vocab=32000
[hf:Snowflake/snowflake-arctic-base; hf]. Arctic's signature dense-MoE
hybrid: every layer runs a dense FFN residual in parallel with the routed
experts (MoEConfig.dense_residual). Full attention -> long_500k skipped.
"""

from repro.models import LayerSpec, MoEConfig, ModelConfig


def build() -> ModelConfig:
    return ModelConfig(
        name="arctic-480b",
        n_layers=35,
        d_model=7168,
        n_heads=56,
        n_kv_heads=8,
        d_ff=4864,
        vocab=32000,
        pattern=(LayerSpec(ffn="moe"),),
        moe=MoEConfig(num_experts=128, top_k=2, dense_residual=True, d_ff=4864),
        rope_theta=10_000.0,
        max_seq=4096,
    )
