"""jit-purity — no host effects inside jit / shard_map / pallas regions.

A traced region runs ONCE at trace time and then replays as compiled XLA:
``print`` fires once (or never again), ``np.random`` freezes one sample
into the graph as a constant, mutating module state bakes in stale values,
and ``.item()`` / ``float()`` / ``int()`` / ``bool()`` on a traced value
either raises a ConcretizationTypeError or — via ``jax.debug`` shims —
forces a device sync that destroys the async dispatch the ingest pipeline
is built on. This rule walks every function reachable from a jit root and
flags those constructs.

Roots: defs decorated with ``jax.jit`` / ``functools.partial(jax.jit, ...)``,
functions passed to ``jax.jit(...)`` / ``shard_map`` /
``sharding.shard_map_rows`` / ``pl.pallas_call`` (directly or through
``functools.partial``). Reachability: intra-module calls by name plus
cross-module ``module.fn`` calls resolved through imports, iterated to a
fixpoint over the whole parse set.

Host-sync detection is deliberately conservative to stay signal-dense:
``float/int/bool`` is flagged when its argument *contains a jnp./jax. call*
(e.g. ``int(jnp.sum(x))``) or, in a jit-root function, is derived from a
non-static parameter (static = named in the root's ``static_argnums`` /
``static_argnames``). Documented host-side entry points that the
reachability over-approximates belong in the baseline with justification.
"""

from __future__ import annotations

import ast

from repro.analysis.astutil import ImportMap, call_keyword, dotted, literal_int_tuple
from repro.analysis.findings import Finding
from repro.analysis.registry import Rule, register

SCOPE = ("src/repro/",)

JIT_ENTRY = {
    "jax.jit",
    "jax.pmap",
    "jax.shard_map",
    "jax.experimental.shard_map.shard_map",
    "repro.core.sharding.shard_map",
    "repro.core.sharding.shard_map_rows",
    "jax.experimental.pallas.pallas_call",
}
_PARTIAL = ("functools.partial", "partial")


def _is_jit_entry(qual: str | None) -> bool:
    if qual is None:
        return False
    return qual in JIT_ENTRY or qual.endswith(".pallas_call") or qual.endswith(
        ".shard_map_rows"
    )


def _contains_traced_call(node: ast.expr, imap: ImportMap) -> bool:
    """True if the expression contains a jnp./jax.-rooted call."""
    for n in ast.walk(node):
        if isinstance(n, ast.Call):
            qual = imap.resolve(n.func) or dotted(n.func) or ""
            root = qual.split(".")[0]
            if root in ("jnp", "jax", "lax") or qual.startswith(
                ("jax.numpy.", "jax.lax.", "jax.")
            ):
                return True
    return False


class _FnInfo:
    """One function def plus where it sits (module, statics if jit root)."""

    def __init__(self, mod, qual: str, node):
        self.mod = mod
        self.qual = qual  # module-local qualname
        self.node = node
        self.is_root = False
        self.static_params: set[str] = set()


def _decorator_statics(fn: ast.AST, imap: ImportMap) -> set[str] | None:
    """Static param names if ``fn`` is decorated as a jit root, else None."""
    for dec in getattr(fn, "decorator_list", []):
        if imap.resolve(dec) == "jax.jit":
            return set()
        if isinstance(dec, ast.Call):
            target = dec.func
            if imap.resolve(target) == "jax.jit":
                return _statics_from_call(dec, fn)
            if imap.resolve(target) in _PARTIAL and dec.args:
                if imap.resolve(dec.args[0]) == "jax.jit":
                    return _statics_from_call(dec, fn)
    return None


def _statics_from_call(call: ast.Call, fn: ast.AST) -> set[str]:
    params = [a.arg for a in fn.args.posonlyargs + fn.args.args]
    statics: set[str] = set()
    nums = literal_int_tuple(call_keyword(call, "static_argnums"))
    for i in nums or ():
        if i < len(params):
            statics.add(params[i])
    names = call_keyword(call, "static_argnames")
    if isinstance(names, ast.Constant) and isinstance(names.value, str):
        statics.add(names.value)
    elif isinstance(names, (ast.Tuple, ast.List)):
        for e in names.elts:
            if isinstance(e, ast.Constant) and isinstance(e.value, str):
                statics.add(e.value)
    return statics


@register
class JitPurityRule(Rule):
    """Flag host-impure constructs in functions reachable from jit roots."""

    name = "jit-purity"
    description = (
        "no print / np.random / module-state mutation / tracer host-syncs "
        "inside functions reachable from jax.jit, shard_map, or pallas_call"
    )

    def run(self, ctx) -> list[Finding]:
        """Run the rule over the context's selected modules."""
        # ---- index every function def across the scope -------------------
        infos: dict[tuple[str, str], _FnInfo] = {}  # (module name, local name)
        imaps: dict[str, ImportMap] = {}
        from repro.analysis.astutil import walk_functions

        for mod in ctx.iter_modules(SCOPE):
            imap = ImportMap(mod.tree, mod.name)
            imaps[mod.name] = imap
            for qual, node in walk_functions(mod.tree):
                info = _FnInfo(mod, qual, node)
                # Index by bare local name: calls use the leaf name. Last
                # writer wins on collision — acceptable for this codebase.
                infos[(mod.name, node.name)] = info
                statics = _decorator_statics(node, imap)
                if statics is not None:
                    info.is_root = True
                    info.static_params = statics

        # ---- roots via jax.jit(fn, ...) / shard_map(fn) / pallas_call(fn)
        for mod in ctx.iter_modules(SCOPE):
            imap = imaps[mod.name]
            for node in ast.walk(mod.tree):
                if not isinstance(node, ast.Call):
                    continue
                if not _is_jit_entry(imap.resolve(node.func)):
                    continue
                target = node.args[0] if node.args else None
                if isinstance(target, ast.Call) and imap.resolve(
                    target.func
                ) in _PARTIAL:
                    target = target.args[0] if target.args else None
                if isinstance(target, ast.Name):
                    info = infos.get((mod.name, target.id))
                    if info is not None:
                        info.is_root = True
                        if imap.resolve(node.func) == "jax.jit":
                            info.static_params |= _statics_from_call(
                                node, info.node
                            )

        # ---- reachability fixpoint ---------------------------------------
        reachable: set[tuple[str, str]] = {
            k for k, info in infos.items() if info.is_root
        }
        work = list(reachable)
        while work:
            key = work.pop()
            info = infos[key]
            imap = imaps[info.mod.name]
            for node in ast.walk(info.node):
                if not isinstance(node, ast.Call):
                    continue
                callee: tuple[str, str] | None = None
                if isinstance(node.func, ast.Name):
                    callee = (info.mod.name, node.func.id)
                else:
                    qual = imap.resolve(node.func)
                    if qual is not None:
                        owner, _, leaf = qual.rpartition(".")
                        if ctx.module_by_name(owner) is not None:
                            callee = (owner, leaf)
                if callee in infos and callee not in reachable:
                    reachable.add(callee)
                    work.append(callee)

        # ---- flag impurities in reachable bodies -------------------------
        findings: list[Finding] = []
        for key in sorted(reachable):
            info = infos[key]
            if not ctx.is_selected(info.mod.rel):
                continue
            findings += self._check_body(info, imaps[info.mod.name])
        return findings

    def _check_body(self, info: _FnInfo, imap: ImportMap) -> list[Finding]:
        out: list[Finding] = []
        mod = info.mod
        fn = info.node
        params = {a.arg for a in fn.args.posonlyargs + fn.args.args}
        traced = params - info.static_params if info.is_root else set()
        module_mutables = self._module_mutables(mod)

        def flag(node, msg):
            out.append(Finding(self.name, mod.rel, node.lineno, msg))

        def walk_own(root):
            # Like ast.walk but does not descend into nested defs — those
            # are their own reachability nodes (lambdas stay inline).
            stack = list(ast.iter_child_nodes(root))
            while stack:
                n = stack.pop()
                if isinstance(n, (ast.FunctionDef, ast.AsyncFunctionDef)):
                    continue
                yield n
                stack.extend(ast.iter_child_nodes(n))

        for node in walk_own(fn):
            if isinstance(node, ast.Global):
                flag(node, f"'global {', '.join(node.names)}' inside a jit-"
                           f"reachable function '{fn.name}'")
            elif isinstance(node, ast.Call):
                qual = imap.resolve(node.func) or dotted(node.func) or ""
                fname = qual.split(".")[-1] if qual else ""
                if qual == "print" or (
                    isinstance(node.func, ast.Name) and node.func.id == "print"
                ):
                    flag(node, f"print() inside jit-reachable '{fn.name}' — "
                               "use jax.debug.print")
                elif qual.startswith(("numpy.random", "np.random")):
                    flag(node, f"np.random inside jit-reachable '{fn.name}' "
                               "freezes one sample at trace time — use "
                               "jax.random with an explicit key")
                elif isinstance(node.func, ast.Attribute) and node.func.attr == "item":
                    flag(node, f".item() inside jit-reachable '{fn.name}' is "
                               "a tracer host-sync")
                elif (
                    isinstance(node.func, ast.Name)
                    and node.func.id in ("float", "int", "bool")
                    and len(node.args) == 1
                ):
                    arg = node.args[0]
                    if _contains_traced_call(arg, imap):
                        flag(node, f"{node.func.id}() over a jnp/jax "
                                   f"expression inside jit-reachable "
                                   f"'{fn.name}' is a tracer host-sync")
                    elif traced:
                        root = (dotted(arg) or "").split(".")[0]
                        if root in traced:
                            flag(node, f"{node.func.id}('{root}') on a traced "
                                       f"parameter of jit root '{fn.name}' is "
                                       "a tracer host-sync")
                elif fname in ("append", "update", "setdefault", "pop") and (
                    isinstance(node.func, ast.Attribute)
                ):
                    base = dotted(node.func.value)
                    if base in module_mutables:
                        flag(node, f"mutation of module-level '{base}' inside "
                                   f"jit-reachable '{fn.name}' bakes in stale "
                                   "state")
            elif isinstance(node, (ast.Assign, ast.AugAssign)):
                targets = (
                    node.targets if isinstance(node, ast.Assign) else [node.target]
                )
                for t in targets:
                    if isinstance(t, ast.Subscript):
                        base = dotted(t.value)
                        if base in module_mutables:
                            flag(t, f"subscript-write to module-level "
                                    f"'{base}' inside jit-reachable "
                                    f"'{fn.name}' bakes in stale state")
        return out

    @staticmethod
    def _module_mutables(mod) -> set[str]:
        """Module-level names bound to dict/list literals or calls."""
        out: set[str] = set()
        for node in mod.tree.body:
            if isinstance(node, ast.Assign) and isinstance(
                node.value, (ast.Dict, ast.List, ast.DictComp, ast.ListComp)
            ):
                for t in node.targets:
                    if isinstance(t, ast.Name):
                        out.add(t.id)
            elif isinstance(node, ast.Assign) and isinstance(node.value, ast.Call):
                if (dotted(node.value.func) or "") in ("dict", "list"):
                    for t in node.targets:
                        if isinstance(t, ast.Name):
                            out.add(t.id)
        return out
