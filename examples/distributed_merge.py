"""Distributed multi-tenant sketching: ONE MILLION tenants over 8 devices.

The production shape of the paper's per-user DAU / per-flow monitoring
settings: a stream of (tenant id, element id, weight) triples where tenant
ids are sparse 64-bit values (org ids, flow hashes), not dense indices.
Three layers cooperate (DESIGN.md §6):

  1. key directory   — tenant id -> slot via stateless hashing, with
                       collision telemetry and a pinned hot-tenant table
                       (core/key_directory.py);
  2. sharded array   — the int8[K, m] register matrix row-sharded over the
                       "sketch" mesh axis with shard_map; each device owns
                       K/8 tenants' registers (core/sharded_array.py);
  3. exact algebra   — registers are max-monoid elements, so per-pod states
                       merge by element-wise max, bit-identical to sketching
                       the union stream.

This demo runs K = 2^20 (~1e6) slots over 8 host devices, streams ~1.6M
keyed elements from ~200k active tenants, merges two independently-built
"pods" by all-max, estimates ALL K weighted cardinalities with the
shard-local vmapped Newton, and cross-checks a tenant sample against exact
truth — plus bit-identity of the merge path against the single-pass state.

    PYTHONPATH=src python examples/distributed_merge.py
    (re-executes itself with XLA_FLAGS for 8 host devices)
"""

import os
import sys
import time

if "XLA_FLAGS" not in os.environ:
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    os.execv(sys.executable, [sys.executable] + sys.argv)

import jax
import numpy as np

from repro.core import SketchConfig, key_directory, sharded_array
from repro.core.key_directory import DirectoryConfig
from repro.launch.mesh import make_sketch_mesh


def main():
    mesh = make_sketch_mesh()
    n_dev = sharded_array.num_shards(mesh)
    cfg = SketchConfig(m=64, b=8, seed=7)

    n_tenants, n_stream, batch = 200_000, 1_600_000, 131_072
    rng = np.random.default_rng(3)
    # Sparse 64-bit tenant universe + a few "billable" hot tenants that get
    # pinned (dedicated, collision-proof) slots.
    tenants = np.unique(rng.integers(0, 2**64, n_tenants + 1024, dtype=np.uint64))[:n_tenants]
    rng.shuffle(tenants)
    hot = tuple(int(t) for t in tenants[:4])
    dcfg = DirectoryConfig(capacity=2**20, seed=11, pinned=hot)
    assert dcfg.capacity % n_dev == 0

    print(f"devices: {n_dev}  tenant slots K = {dcfg.capacity:,}  m = {cfg.m}")
    print(f"register matrix: {dcfg.capacity * cfg.m / 2**20:.0f} MiB int8 "
          f"-> {dcfg.capacity * cfg.m / n_dev / 2**20:.0f} MiB/device (row-sharded)")

    # Zipf-ish tenant activity; per-(tenant, element) weights.
    t_idx = rng.zipf(1.2, n_stream) % n_tenants
    ids = rng.integers(0, 2**32, n_stream, dtype=np.uint32)
    w = (rng.gamma(1.0, 2.0, n_stream) + 1e-5).astype(np.float32)

    st = sharded_array.init(cfg, dcfg.capacity, mesh)
    dstate = key_directory.init(dcfg)
    t0 = time.perf_counter()
    for i in range(0, n_stream, batch):
        sl = slice(i, i + batch)
        lo, hi = key_directory.split_uint64(tenants[t_idx[sl]])
        st, dstate = sharded_array.update_tenants(
            cfg, dcfg, mesh, st, dstate, (lo, hi),
            np.ascontiguousarray(ids[sl]), np.ascontiguousarray(w[sl]),
        )
    jax.block_until_ready(st.regs)
    dt = time.perf_counter() - t0
    print(f"streamed {n_stream:,} elements in {dt:.2f}s "
          f"({n_stream / dt / 1e6:.1f} M elements/s into {dcfg.capacity:,} sharded sketches)")
    print(f"directory: occupancy {float(key_directory.occupancy(dstate)):.1%}, "
          f"collision rate {float(key_directory.collision_rate(dstate)):.3%} of routings")

    # --- the cross-POD form: two half-streams sketched independently, then
    # merged by all-max. Bit-identical to the single-pass state above.
    half = n_stream // 2
    pods = []
    for a, b in ((0, half), (half, n_stream)):
        ps, pd = sharded_array.init(cfg, dcfg.capacity, mesh), key_directory.init(dcfg)
        for i in range(a, b, batch):
            sl = slice(i, min(i + batch, b))
            lo, hi = key_directory.split_uint64(tenants[t_idx[sl]])
            ps, pd = sharded_array.update_tenants(
                cfg, dcfg, mesh, ps, pd, (lo, hi),
                np.ascontiguousarray(ids[sl]), np.ascontiguousarray(w[sl]),
            )
        pods.append(ps)
    merged = sharded_array.merge(pods[0], pods[1])
    same = bool(np.array_equal(np.asarray(merged.regs), np.asarray(st.regs)))
    print(f"2-pod all-max merge == single-pass registers: {same}")
    print(f"wire cost of a full cross-pod merge: {dcfg.capacity * cfg.m / 2**20:.0f} MiB "
          f"(all-reduce-max, {cfg.m} B/tenant)")

    # --- estimate ALL K slots: vmapped Newton, local to each shard.
    t0 = time.perf_counter()
    est = np.asarray(sharded_array.estimate_all(cfg, mesh, st))
    dt = time.perf_counter() - t0
    print(f"estimate_all over K = {dcfg.capacity:,}: {dt:.2f}s "
          f"({dt / dcfg.capacity * 1e6:.1f} us/tenant, shard-local Newton)")

    # --- accuracy spot check: exact truth for a sample of busy tenants.
    slots_all = np.asarray(key_directory.route_slots(
        dcfg, key_directory.split_uint64(tenants[t_idx])))
    true_by_slot = {}
    active = np.unique(t_idx)
    # Pinned hot tenants that actually saw traffic, plus a random active set.
    sample = [t for t in range(4) if np.isin(t, active)]
    n_pinned_sampled = len(sample)
    sample += list(rng.choice(active, size=24, replace=False))
    for t in sample:
        sel = t_idx == t
        uniq = np.unique(ids[sel], return_index=True)[1]
        slot = int(slots_all[np.nonzero(sel)[0][0]])
        true_by_slot.setdefault(slot, 0.0)
        true_by_slot[slot] += float(w[sel][uniq].astype(np.float64).sum())
    errs = [abs(est[s] - c) / c for s, c in true_by_slot.items() if c > 0]
    print(f"sampled {len(true_by_slot)} tenants (incl. {n_pinned_sampled} pinned): "
          f"median rel. err {np.median(errs):.2%} (m={cfg.m} registers/tenant)")


if __name__ == "__main__":
    main()
