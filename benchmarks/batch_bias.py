"""Beyond-paper: QSketch-Dyn batch-mode staleness bias vs batch size.

The TPU-native batch mode computes every q_R from the batch-START histogram
(DESIGN.md §4.2). This measures |Ĉ_batch - Ĉ_exact| / C over batch sizes —
the result (bias << sketch noise for B <= 4096 at m=256) is what licenses
the batched execution mode.
"""

from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from repro.core import SketchConfig, qsketch_dyn
from repro.data import synthetic

from . import common


def run(quick=True):
    n = 8_000 if quick else 32_000
    runs = 10 if quick else 30
    batch_sizes = [64, 512, 4096] if quick else [64, 256, 1024, 4096, 16384]
    m = 256
    rows = []
    rel_gap, rel_exact = {}, []
    for r in range(runs):
        ids, w, true_c = synthetic.stream("gamma", n, seed=300 + r)
        cfg = SketchConfig(m=m, b=8, seed=400 + r)
        exact = qsketch_dyn.update_scan(cfg, qsketch_dyn.init(cfg), jnp.asarray(ids), jnp.asarray(w))
        ce = float(exact.chat)
        rel_exact.append((ce - true_c) / true_c)
        for bs in batch_sizes:
            st = qsketch_dyn.init(cfg)
            for i in range(0, n, bs):
                st = qsketch_dyn.update_batch(cfg, st, jnp.asarray(ids[i : i + bs]), jnp.asarray(w[i : i + bs]))
            rel_gap.setdefault(bs, []).append((float(st.chat) - ce) / true_c)
    sketch_noise = float(np.sqrt(np.mean(np.square(rel_exact))))
    for bs in batch_sizes:
        gap = float(np.sqrt(np.mean(np.square(rel_gap[bs]))))
        rows.append({
            "figure": "batch_bias",
            "batch_size": bs,
            "rms_gap_vs_exact": gap,
            "sketch_rrmse": sketch_noise,
            "gap_over_noise": gap / max(sketch_noise, 1e-12),
            "m": m,
            "n": n,
            "runs": runs,
        })
        common.csv_row(f"batch_bias/B{bs}", 0.0, f"gap/noise={gap/max(sketch_noise,1e-12):.3f}")
    common.save("batch_bias", rows)
    return rows
