"""Unit tests for the logical-axis -> mesh-axis resolver (no device mesh ops,
just spec construction against 2- and 3-axis meshes)."""

import jax
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.models import sharding as ms
from repro.models.common import ParamDef


def _abstract_mesh(sizes, names):
    """AbstractMesh across the signature change: newer JAX takes
    (axis_sizes, axis_names); 0.4.x takes ((name, size), ...) pairs."""
    from jax.sharding import AbstractMesh

    try:
        return AbstractMesh(sizes, names)
    except TypeError:
        return AbstractMesh(tuple(zip(names, sizes)))


@pytest.fixture(scope="module")
def meshes():
    # Abstract meshes: no XLA device initialization issues on CPU (uses the
    # single real device repeated logically via AbstractMesh).
    two = _abstract_mesh((16, 16), ("data", "model"))
    three = _abstract_mesh((2, 16, 16), ("pod", "data", "model"))
    return two, three


def test_model_class_divisibility(meshes):
    two, _ = meshes
    # heads=32 divides 16 -> sharded; heads=8 does not -> replicated dim.
    assert ms.resolve(("embed", "heads", None), two, (4096, 32, 128)) == P(("data",), "model", None)
    assert ms.resolve(("embed", "kv_heads", None), two, (4096, 8, 128)) == P(("data",), None, None)


def test_fsdp_class_divisibility_and_fallback(meshes):
    two, three = meshes
    # 4096 % 16 == 0 -> data-sharded.
    assert ms.resolve(("embed",), two, (4096,)) == P(("data",))
    # 4097 not divisible -> replicated.
    assert ms.resolve(("embed",), two, (4097,)) == P(None)
    # 3-axis: (pod,data) product 32; 64 divisible -> both axes.
    assert ms.resolve(("batch", None), three, (64, 7)) == P(("pod", "data"), None)
    # 2 only divisible by pod -> prefix fallback.
    assert ms.resolve(("batch", None), three, (2, 7)) == P(("pod",), None)


def test_seq_model_axis(meshes):
    two, _ = meshes
    assert ms.resolve(("batch", "seq_model", None, None), two, (128, 32768, 8, 128)) == P(
        ("data",), "model", None, None
    )


def test_unknown_axis_raises(meshes):
    two, _ = meshes
    with pytest.raises(ValueError):
        ms.resolve(("bogus",), two, (8,))


def test_spec_tree_structure(meshes):
    two, _ = meshes
    defs = {
        "a": ParamDef((4096, 32, 128), ("embed", "heads", None)),
        "n": {"b": ParamDef((256,), (None,))},
    }
    tree = ms.spec_tree(defs, two)
    assert tree["a"] == P(("data",), "model", None)
    assert tree["n"]["b"] == P(None)


def test_full_configs_have_no_duplicate_axes(meshes):
    """Every ParamDef in every full config must resolve to a valid spec
    (no mesh axis used twice in one spec) on both production meshes."""
    from repro import configs
    from repro.models import transformer
    from repro.models.common import _leaf_paths

    two, three = meshes
    for arch in configs.list_archs():
        cfg = configs.get_config(arch)
        for mesh in (two, three):
            for path, d in _leaf_paths(transformer.model_defs(cfg)):
                spec = ms.resolve(d.axes, mesh, d.shape)
                flat = [a for part in spec if part for a in (part if isinstance(part, tuple) else (part,))]
                assert len(flat) == len(set(flat)), (arch, path, spec)
            for path, d in _leaf_paths(transformer.cache_defs(cfg, 8, 64)):
                spec = ms.resolve(d.axes, mesh, d.shape)
                flat = [a for part in spec if part for a in (part if isinstance(part, tuple) else (part,))]
                assert len(flat) == len(set(flat)), (arch, "cache", path, spec)
