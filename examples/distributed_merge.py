"""Distributed sketch merging: the multi-pod telemetry pattern, on 8 local
devices.

The stream is sharded over a ("data",) mesh axis (as a training batch would
be); each shard folds its elements into the shared QSketch state inside one
jit — GSPMD turns the register combine into an all-reduce-max of 512 BYTES,
which is the entire cross-fleet cost of global weighted-cardinality
telemetry. The result is bit-identical to sketching the unsharded stream.

    PYTHONPATH=src python examples/distributed_merge.py
    (re-executes itself with XLA_FLAGS for 8 host devices)
"""

import os
import sys

if "XLA_FLAGS" not in os.environ:
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    os.execv(sys.executable, [sys.executable] + sys.argv)

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.core import SketchConfig, qsketch
from repro.data import synthetic


def main():
    mesh = jax.make_mesh((8,), ("data",))
    cfg = SketchConfig(m=512, b=8, seed=7)

    ids, weights, true_c = synthetic.with_repeats("gamma", 20_000, 80_000, seed=1)
    ids_sh = jax.device_put(ids, NamedSharding(mesh, P("data")))
    w_sh = jax.device_put(weights, NamedSharding(mesh, P("data")))

    @jax.jit
    def sketch_global(i, w):
        # Batch is sharded over 'data'; registers replicated. XLA inserts the
        # (tiny) all-reduce-max automatically.
        return qsketch.update(cfg, qsketch.init(cfg), i, w)

    st = sketch_global(ids_sh, w_sh)
    est = float(qsketch.estimate(cfg, st))

    # Reference: same stream, single device.
    st_ref = qsketch.update(cfg, qsketch.init(cfg), jnp.asarray(ids), jnp.asarray(weights))

    print(f"devices: {len(jax.devices())}  mesh: {dict(zip(mesh.axis_names, mesh.devices.shape))}")
    print(f"true C = {true_c:,.1f}   sharded-sketch estimate = {est:,.1f} "
          f"({abs(est-true_c)/true_c:.2%} err)")
    print("sharded registers == single-device registers:",
          bool(np.array_equal(np.asarray(st.regs), np.asarray(st_ref.regs))))
    print(f"wire cost of global telemetry: {cfg.m * cfg.b // 8} bytes/merge (all-reduce-max)")

    # Explicit merge of independently-built shard sketches (the cross-POD
    # form, where shards live in different jit programs/pods entirely).
    shards = np.array_split(np.arange(len(ids)), 8)
    states = [
        qsketch.update(cfg, qsketch.init(cfg), jnp.asarray(ids[s]), jnp.asarray(weights[s]))
        for s in shards
    ]
    merged = states[0]
    for s in states[1:]:
        merged = qsketch.merge(merged, s)
    print("explicit 8-way merge == global sketch:",
          bool(np.array_equal(np.asarray(merged.regs), np.asarray(st_ref.regs))))


if __name__ == "__main__":
    main()
