"""Pallas TPU kernel: fused per-row bincount + fixed-iteration MLE solve.

``estimation.estimate_rows(solver="fused")`` answers "Ĉ for every register
row" without ever materializing the ``[K, 2^b]`` histogram block in HBM: the
jnp path builds that block (1 GB at K = 2^20, b = 8) just to reduce it again.
This kernel streams ``block_k`` register rows at a time through VMEM and does
both stages on the resident tile:

  grid = (K_pad / block_k,), blocks independent ("parallel"): each step
  bincounts its (block_k × m_pad) int8 tile into a VMEM scratch histogram —
  the window_union idiom, a fori_loop of masked lane reductions — then runs
  the rebased safeguarded Newton of ``estimators.qsketch_mle`` on the
  (block_k × 2^b) scratch, vectorized across the block's rows, for a FIXED
  ``_N_ITERS`` iterations (kernels cannot data-dependently early-exit a
  while_loop per lane; 30 capped 8×-per-step iterations cover the worst
  collapse trajectory to the 1e-30 floor). Only the three (block_k, 1)
  result columns ever leave the kernel.

The solve replicates ``estimators._f_and_fprime`` term-for-term on tiles
(interior / bin-0 / top-bin selected by a lane iota), including the rebase
Δ = round(mean register value) and the degenerate fallbacks, so agreement
with the ``newton`` solver is bounded only by the fixed-vs-adaptive
iteration count (tested against the float64 reference at LUT tolerance).

Built for TPU; on CPU it runs in interpret mode (Python-executed kernel
body — validation speed only, use ``solver="lut"`` there).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from . import compat

DEFAULT_BLOCK_K = 256
_N_ITERS = 30
_EPS_Z = 1e-4  # series-switch threshold for z = C*s (estimators._EPS_Z)


def _estimate_kernel(
    regs_ref, chat_ref, std_ref, conv_ref, hist_ref, *, m, nb_padded, r_min, top_bin
):
    u = regs_ref[...].astype(jnp.int32)  # (block_k, m_pad)
    lane_valid = jax.lax.broadcasted_iota(jnp.int32, u.shape, 1) < m

    def bin_body(v, _):
        cnt = jnp.sum(
            jnp.where(lane_valid & (u == v + r_min), 1.0, 0.0),
            axis=1,
            keepdims=True,
        )
        hist_ref[:, pl.ds(v, 1)] = cnt.astype(jnp.float32)
        return _

    jax.lax.fori_loop(0, nb_padded, bin_body, None)

    t = hist_ref[...]  # (block_k, nb_pad) f32, rows sum to m
    lane = jax.lax.broadcasted_iota(jnp.int32, t.shape, 1)
    kval = lane.astype(jnp.float32) + float(r_min)

    # Rebase (estimators.qsketch_mle): Δ = round(mean register value).
    delta = jnp.round(jnp.sum(t * kval, axis=1, keepdims=True) / m)
    expo = jnp.clip(delta - (kval + 1.0), -126.0, 126.0)
    s = jnp.exp2(expo)

    c0 = (m - 1) / jnp.maximum(
        jnp.sum(t * s * 2.0, axis=1, keepdims=True), jnp.float32(1e-30)
    )
    c0 = jnp.clip(c0, jnp.float32(1e-20), jnp.float32(1e20))

    t0 = t[:, 0:1]
    tt = t[:, top_bin : top_bin + 1]
    degenerate = (t0 == m) | (tt == m)

    s_bot = s[:, 0:1]
    a = 2.0 * s[:, top_bin : top_bin + 1]

    def f_and_fprime(c):
        z = c * s
        zz = jnp.clip(z, _EPS_Z, 88.0)
        f_int = jnp.where(z < _EPS_Z, 1.0 / c - 0.5 * s, s / jnp.expm1(zz)) - s
        lsh = jnp.where(
            zz > 40.0, zz / 2.0, jnp.log(2.0 * jnp.sinh(jnp.minimum(zz, 40.0) / 2.0))
        )
        fp_int = jnp.where(
            z < _EPS_Z, -1.0 / (c * c), -jnp.exp(2.0 * (jnp.log(s) - lsh))
        )

        za = c * a
        zza = jnp.clip(za, _EPS_Z, 88.0)
        f_top = jnp.where(za < _EPS_Z, 1.0 / c - 0.5 * a, a / jnp.expm1(zza))
        lsha = jnp.where(
            zza > 40.0, zza / 2.0, jnp.log(2.0 * jnp.sinh(jnp.minimum(zza, 40.0) / 2.0))
        )
        fp_top = jnp.where(
            za < _EPS_Z, -1.0 / (c * c), -jnp.exp(2.0 * (jnp.log(a) - lsha))
        )

        f_terms = jnp.where(lane == 0, -s_bot, jnp.where(lane == top_bin, f_top, f_int))
        fp_terms = jnp.where(
            lane == 0, jnp.float32(0.0), jnp.where(lane == top_bin, fp_top, fp_int)
        )
        f = jnp.sum(t * f_terms, axis=1, keepdims=True)
        fp = jnp.sum(t * fp_terms, axis=1, keepdims=True)
        return f, fp

    def newton_body(_, c):
        f, fp = f_and_fprime(c)
        step = f / jnp.where(jnp.abs(fp) > 0, fp, jnp.float32(-1e-30))
        c_new = jnp.clip(c - step, c / 8.0, c * 8.0)
        c_new = jnp.maximum(c_new, jnp.float32(1e-30))
        return jnp.where(degenerate, c, c_new)

    c = jax.lax.fori_loop(0, _N_ITERS, newton_body, c0)
    _, fp = f_and_fprime(c)
    std = jnp.sqrt(
        jnp.maximum(-1.0 / jnp.where(jnp.abs(fp) > 0, fp, jnp.float32(-1e-30)), 0.0)
    )
    scale_back = jnp.exp2(delta)
    chat = jnp.where(t0 == m, jnp.float32(0.0), c * scale_back)

    chat_ref[...] = chat
    std_ref[...] = std * scale_back
    conv_ref[...] = jnp.where(degenerate, 0, 1).astype(jnp.int32)


@functools.partial(
    jax.jit, static_argnames=("m", "nb_padded", "r_min", "top_bin", "block_k", "interpret")
)
def estimate_rows_padded(
    regs,
    *,
    m: int,
    nb_padded: int,
    r_min: int,
    top_bin: int,
    block_k: int = DEFAULT_BLOCK_K,
    interpret: bool = False,
):
    """Kernel entry on pre-padded operands.

    regs: (K_pad, m_pad) int8, K_pad % block_k == 0, m_pad % 128 == 0, pad
      rows/lanes at r_min (padded lanes are excluded from the bincount by an
      iota mask; padded rows solve to the degenerate 0 and are sliced off by
      the wrapper).
    Returns (chat (K_pad, 1) f32, stddev (K_pad, 1) f32, conv (K_pad, 1)
    int32) — the unscaled per-row MLE triple; ``ops.estimate_rows_op``
    applies the kind convention.
    """
    kp, mp = regs.shape
    kernel = functools.partial(
        _estimate_kernel, m=m, nb_padded=nb_padded, r_min=r_min, top_bin=top_bin
    )
    return pl.pallas_call(
        kernel,
        grid=(kp // block_k,),
        in_specs=[pl.BlockSpec((block_k, mp), lambda ki: (ki, 0))],
        out_specs=[
            pl.BlockSpec((block_k, 1), lambda ki: (ki, 0)),
            pl.BlockSpec((block_k, 1), lambda ki: (ki, 0)),
            pl.BlockSpec((block_k, 1), lambda ki: (ki, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((kp, 1), jnp.float32),
            jax.ShapeDtypeStruct((kp, 1), jnp.float32),
            jax.ShapeDtypeStruct((kp, 1), jnp.int32),
        ],
        scratch_shapes=[pltpu.VMEM((block_k, nb_padded), jnp.float32)],
        compiler_params=compat.CompilerParams(dimension_semantics=("parallel",)),
        interpret=interpret,
    )(regs)
