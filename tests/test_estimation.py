"""Unified estimation layer (core/estimation.py) tests.

Three contracts (DESIGN.md §8.7):

1. **Bit-identity** — ``solver="newton"`` must reproduce the pre-refactor
   per-container solves *exactly*. The goldens below were captured from the
   repo before any caller was re-pointed at the estimation layer (the same
   stream recipe each time: m=64, b=8, K=16, B=4096, rng(7)); every container
   — including the three sharded fronts on the 8-device host mesh — must hit
   them to the last bit.

2. **Tolerance** — ``solver="lut"`` and the fused Pallas kernel agree with
   the float64 reference (``estimators.mle_numpy``) within the documented
   combined tolerance |Δ| <= ATOL_FLOOR + LUT_RTOL·|ref| across an (m, b)
   grid. The absolute floor covers collapse rows (bin-0 mass next to
   high-bin mass drives the f32 *and* f64 MLE to ~0 — seed behaviour, not a
   solver artifact).

3. **Guard dedup** — the untouched-row Ĉ=0 guard now lives in ONE place
   (``estimation._routed_chat`` / the in-solver degenerate-low path);
   every routed container must still report exact 0.0 for untouched rows.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (
    SketchConfig,
    dyn_array,
    estimation,
    estimators,
    qsketch,
    qsketch_dyn,
    sharded_array,
    sharded_dyn_array,
    sharded_window_array,
    sketch_array,
    window_array,
)
from repro.kernels import ops
from repro.launch.mesh import make_sketch_mesh

CFG = SketchConfig(m=64, b=8)
K = 16
B = 4096


@pytest.fixture(scope="module")
def mesh():
    return make_sketch_mesh()  # 8 shards under scripts/test.sh


@pytest.fixture(scope="module")
def stream():
    rng = np.random.default_rng(7)
    ids = jnp.asarray(rng.integers(0, 1 << 62, size=B, dtype=np.uint64))
    weights = jnp.asarray((rng.gamma(2.0, 2.0, size=B) + 0.05).astype(np.float32))
    keys = jnp.asarray(rng.integers(0, K, size=B).astype(np.int32))
    return ids, weights, keys


# ---------------------------------------------------------------------------
# pre-refactor goldens (newton bit-identity)
# ---------------------------------------------------------------------------

GOLD_QSKETCH_CHAT = 17106.220703125
GOLD_QSKETCH_STD = 2218.718505859375

GOLD_SA_CHATS = [
    935.5822143554688, 864.5228271484375, 980.6492919921875, 1125.72265625,
    1092.1175537109375, 1298.460693359375, 930.1397705078125, 800.5869750976562,
    1060.95458984375, 1137.4371337890625, 1325.7677001953125, 1363.4495849609375,
    938.951904296875, 1084.2130126953125, 1186.6209716796875, 1148.4674072265625,
]
GOLD_SA_STDS = [
    120.88327026367188, 111.893310546875, 127.64154052734375, 145.25457763671875,
    141.06802368164062, 167.89527893066406, 121.20880889892578, 103.5263442993164,
    137.50711059570312, 146.7041015625, 171.20982360839844, 176.52880859375,
    122.13787078857422, 140.13552856445312, 154.2786407470703, 148.9996337890625,
]

# DynArray routes each element to ONE register row (m-way split per key), so
# per-key MLEs are tiny/collapsed at this K·m vs B ratio — that is seed
# behaviour the refactor must preserve bit-for-bit, collapse values included.
GOLD_DYN_MLE = [
    4.0000000126843074e-30, 1.600000005073723e-29, 2.5600000081179567e-28,
    1.600000005073723e-29, 2.5600000081179567e-28, 1080.39111328125,
    697.1915283203125, 2.5600000081179567e-28, 2.5600000081179567e-28,
    398.16497802734375, 784.46435546875, 2.5600000081179567e-28,
    2.5600000081179567e-28, 1.600000005073723e-29, 1051.5003662109375,
    5.1200000162359135e-28,
]

GOLD_QDYN_MLE = 18447.494140625
GOLD_QDYN_MERGE = 18447.494140625

GOLD_WIN_SUB2 = [
    7.812496351354192e-33, 1.1102233481252159e-36, 5.000000015855384e-31,
    1.953125924548471e-33, 1.250000003963846e-31, 7.812496351354192e-33,
    1.250000003963846e-31, 7.812496351354192e-33, 7.812496351354192e-33,
    6.25000001981923e-32, 1.250000003963846e-31, 7.812496351354192e-33,
    1.953125924548471e-33, 7.812496351354192e-33, 2.0000000063421537e-30,
    1.953125924548471e-33,
]
GOLD_WIN_EPOCHS_HEAD = [
    0.0, 2.2204477724476523e-36, 0.0, 6.115608370297246e-36,
    0.0, 1.1102233481252159e-36, 0.0, 0.0,
]


def _states(stream):
    ids, weights, keys = stream
    st = qsketch.update(CFG, qsketch.init(CFG), ids, weights)
    sa = sketch_array.update(CFG, sketch_array.init(CFG, K), keys, ids, weights)
    da = dyn_array.update_batch(CFG, dyn_array.init(CFG, K), keys, ids, weights)
    wa = window_array.init(CFG, K, 4)
    for epoch in range(4):
        lo, hi = epoch * (B // 4), (epoch + 1) * (B // 4)
        wa = window_array.update_batch(
            CFG, wa, keys[lo:hi], ids[lo:hi], weights[lo:hi]
        )
        if epoch < 3:
            wa = window_array.rotate(CFG, wa)
    return st, sa, da, wa


@pytest.fixture(scope="module")
def states(stream):
    return _states(stream)


def test_newton_bit_identical_qsketch(states):
    st = states[0]
    chat, std, conv = qsketch.estimate_with_ci(CFG, st)
    assert float(chat) == GOLD_QSKETCH_CHAT
    assert float(std) == GOLD_QSKETCH_STD
    assert bool(conv)
    assert float(qsketch.estimate(CFG, st)) == GOLD_QSKETCH_CHAT


def test_newton_bit_identical_sketch_array(states):
    sa = states[1]
    chats, stds, convs = sketch_array.estimate_all_with_ci(CFG, sa)
    assert np.asarray(chats).tolist() == GOLD_SA_CHATS
    assert np.asarray(stds).tolist() == GOLD_SA_STDS
    assert np.asarray(sketch_array.estimate_all(CFG, sa)).tolist() == GOLD_SA_CHATS


def test_newton_bit_identical_dyn_array(states):
    da = states[2]
    assert np.asarray(dyn_array.estimate_mle_all(CFG, da)).tolist() == GOLD_DYN_MLE


def test_newton_bit_identical_qsketch_dyn(stream):
    ids, weights, _ = stream
    qd = qsketch_dyn.update_batch(CFG, qsketch_dyn.init(CFG), ids, weights)
    assert float(qsketch_dyn.estimate_mle(CFG, qd)) == GOLD_QDYN_MLE
    half_a = qsketch_dyn.update_batch(CFG, qsketch_dyn.init(CFG), ids[:2048], weights[:2048])
    half_b = qsketch_dyn.update_batch(CFG, qsketch_dyn.init(CFG), ids[2048:], weights[2048:])
    merged = qsketch_dyn.merge(CFG, half_a, half_b)
    assert float(merged.chat) == GOLD_QDYN_MERGE


def test_newton_bit_identical_window_array(states):
    wa = states[3]
    full = window_array.estimate_window(CFG, wa, 4)
    assert np.asarray(full).tolist() == GOLD_DYN_MLE  # full ring == dyn union
    sub = window_array.estimate_window(CFG, wa, 2)
    assert np.asarray(sub).tolist() == GOLD_WIN_SUB2
    ep = np.asarray(window_array.estimate_epochs_all(CFG, wa)).reshape(-1)
    assert ep[:8].tolist() == GOLD_WIN_EPOCHS_HEAD


def test_newton_bit_identical_sharded_fronts(states, mesh):
    _, sa, da, wa = states
    sh = sharded_array.from_array(sa, mesh)
    assert np.asarray(sharded_array.estimate_all(CFG, mesh, sh)).tolist() == GOLD_SA_CHATS
    sd = sharded_dyn_array.from_array(da, mesh)
    assert np.asarray(sharded_dyn_array.estimate_mle_all(CFG, mesh, sd)).tolist() == GOLD_DYN_MLE
    sw = sharded_window_array.from_array(wa, mesh)
    assert (
        np.asarray(sharded_window_array.estimate_window(CFG, mesh, sw, 4)).tolist()
        == GOLD_DYN_MLE
    )
    assert (
        np.asarray(sharded_window_array.estimate_window(CFG, mesh, sw, 2)).tolist()
        == GOLD_WIN_SUB2
    )


def test_newton_matches_vmapped_reference_form(states):
    """estimate_hists(kind="full") IS the vmapped estimators.qsketch_mle."""
    sa = states[1]
    hists = sketch_array.histograms(CFG, sa)
    got = estimation.estimate_hists(CFG, hists, kind="full", solver="newton")
    ref = jax.vmap(lambda h: estimators.qsketch_mle(CFG, h)[0])(hists)
    assert np.array_equal(np.asarray(got), np.asarray(ref))


# ---------------------------------------------------------------------------
# lut / fused vs the float64 reference (tolerance contract)
# ---------------------------------------------------------------------------


def _within_tol(got, ref):
    got = np.asarray(got, np.float64)
    ref = np.asarray(ref, np.float64)
    return np.abs(got - ref) <= estimation.ATOL_FLOOR + estimation.LUT_RTOL * np.abs(ref)


def _grid_regs(cfg, n_rows, seed):
    """n_rows sketches at wildly different scales (weights 2^-8 .. 2^20)."""
    rng = np.random.default_rng(seed)
    rows = []
    for i in range(n_rows):
        n = int(rng.integers(4, 2000))
        ids = jnp.asarray(rng.integers(0, 1 << 62, size=n, dtype=np.uint64))
        scale = float(2.0 ** rng.uniform(-8, 20))
        w = jnp.asarray((rng.gamma(2.0, 2.0, size=n) * scale + 1e-6).astype(np.float32))
        rows.append(qsketch.update(cfg, qsketch.init(cfg), ids, w).regs)
    return jnp.stack(rows)


@pytest.mark.parametrize("m,b", [(16, 4), (64, 6), (64, 8), (256, 8)])
def test_lut_within_tolerance_of_f64_reference(m, b):
    cfg = SketchConfig(m=m, b=b)
    regs = _grid_regs(cfg, 12, seed=100 + m + b)
    hists = jax.vmap(lambda r: estimators.histogram(cfg, r))(regs)
    got = estimation.estimate_hists(cfg, hists, kind="full", solver="lut")
    ref = np.array([estimators.mle_numpy(cfg, np.asarray(r)) for r in regs])
    ok = _within_tol(got, ref)
    assert ok.all(), f"lut out of tolerance: got={np.asarray(got)[~ok]} ref={ref[~ok]}"


@pytest.mark.parametrize("m,b", [(16, 4), (64, 8), (256, 8)])
def test_fused_within_tolerance_of_f64_reference(m, b):
    cfg = SketchConfig(m=m, b=b)
    regs = _grid_regs(cfg, 10, seed=200 + m + b)
    chat, std, conv = ops.estimate_rows_op(cfg, regs, kind="full")
    ref = np.array([estimators.mle_numpy(cfg, np.asarray(r)) for r in regs])
    ok = _within_tol(chat, ref)
    assert ok.all(), f"fused out of tolerance: got={np.asarray(chat)[~ok]} ref={ref[~ok]}"
    assert np.asarray(std).shape == (10,)
    assert np.asarray(conv).dtype == np.bool_


def test_lut_family_consts_shared_across_configs():
    """The LUT solver tables are cached per (num_bins, r_min, top_bin)
    FAMILY, not per container/config instance: configs differing only in m
    or seed must hand back the very same device arrays (no rebuild, no
    re-upload), and the shared tables must still solve within the
    documented tolerance for each config."""
    a = SketchConfig(m=64, b=8, seed=1)
    c = SketchConfig(m=256, b=8, seed=9)
    ta = estimation.lut_family_consts(a.num_bins, a.r_min, a.top_bin)
    tc = estimation.lut_family_consts(c.num_bins, c.r_min, c.top_bin)
    assert ta[0] is tc[0] and ta[1] is tc[1], "same family rebuilt its tables"
    # A different family must NOT share.
    d = SketchConfig(m=64, b=6)
    td = estimation.lut_family_consts(d.num_bins, d.r_min, d.top_bin)
    assert td[0] is not ta[0]
    # Golden accuracy through the shared tables, per config.
    for cfg in (a, c):
        regs = _grid_regs(cfg, 6, seed=300 + cfg.m)
        hists = jax.vmap(lambda r: estimators.histogram(cfg, r))(regs)
        got = estimation.estimate_hists(cfg, hists, kind="full", solver="lut")
        ref = np.array([estimators.mle_numpy(cfg, np.asarray(r)) for r in regs])
        ok = _within_tol(got, ref)
        assert ok.all(), f"m={cfg.m}: {np.asarray(got)[~ok]} vs {ref[~ok]}"


def test_fused_conv_matches_newton(states):
    sa = states[1]
    _, _, conv_n = sketch_array.estimate_all_with_ci(CFG, sa)
    _, _, conv_f = ops.estimate_rows_op(CFG, sa.regs, kind="full")
    assert np.array_equal(np.asarray(conv_n), np.asarray(conv_f))


def test_lut_chunked_matches_unchunked():
    """K > _LUT_CHUNK goes through lax.map with per-chunk grids + edge pad;
    each row's answer must still meet tolerance vs its own unchunked solve."""
    cfg = SketchConfig(m=16, b=6)
    k = estimation._LUT_CHUNK + 37  # forces the chunked path with a ragged tail
    rng = np.random.default_rng(5)
    # Synthetic histograms: random register draws per row at varied scales.
    regs = jnp.asarray(
        rng.integers(cfg.r_min, cfg.r_max + 1, size=(k, cfg.m), dtype=np.int64).astype(np.int8)
    )
    hists = jax.vmap(lambda r: estimators.histogram(cfg, r))(regs)
    got = estimation.estimate_hists(cfg, hists, kind="full", solver="lut")
    sample = np.asarray([0, 1, 4095, 8191, 8192, k - 1])
    ref = estimation.estimate_hists(cfg, hists[sample], kind="full", solver="lut")
    # Tolerance (not equality): the chunk a row lands in sets its grid anchor.
    combined = np.abs(np.asarray(got)[sample] - np.asarray(ref))
    assert (
        combined <= estimation.ATOL_FLOOR + estimation.LUT_RTOL * np.abs(np.asarray(ref))
    ).all()
    assert got.shape == (k,)


# ---------------------------------------------------------------------------
# lut through the containers (tolerance vs their newton answers)
# ---------------------------------------------------------------------------


def test_lut_through_containers(states, mesh):
    _, sa, da, wa = states
    newton = np.asarray(sketch_array.estimate_all(CFG, sa), np.float64)
    lut = np.asarray(sketch_array.estimate_all(CFG, sa, solver="lut"), np.float64)
    assert _within_tol(lut, newton).all()

    dyn_newton = np.asarray(dyn_array.estimate_mle_all(CFG, da), np.float64)
    dyn_lut = np.asarray(dyn_array.estimate_mle_all(CFG, da, solver="lut"), np.float64)
    assert _within_tol(dyn_lut, dyn_newton).all()

    win_newton = np.asarray(window_array.estimate_window(CFG, wa, 2), np.float64)
    win_lut = np.asarray(window_array.estimate_window(CFG, wa, 2, solver="lut"), np.float64)
    assert _within_tol(win_lut, win_newton).all()

    # Sharded lut: per-shard grids -> tolerance-level agreement with the host.
    sh = sharded_array.from_array(sa, mesh)
    sh_lut = np.asarray(sharded_array.estimate_all(CFG, mesh, sh, solver="lut"), np.float64)
    assert _within_tol(sh_lut, newton).all()
    sd = sharded_dyn_array.from_array(da, mesh)
    sd_lut = np.asarray(
        sharded_dyn_array.estimate_mle_all(CFG, mesh, sd, solver="lut"), np.float64
    )
    assert _within_tol(sd_lut, dyn_newton).all()
    sw = sharded_window_array.from_array(wa, mesh)
    sw_lut = np.asarray(
        sharded_window_array.estimate_window(CFG, mesh, sw, 2, solver="lut"), np.float64
    )
    assert _within_tol(sw_lut, win_newton).all()


# ---------------------------------------------------------------------------
# untouched-row guard (the deduplicated Ĉ=0 contract)
# ---------------------------------------------------------------------------


def test_untouched_rows_exact_zero_everywhere(stream):
    ids, weights, keys = stream
    sel = np.asarray(keys) < 13  # rows 13..15 never touched
    ids_s, w_s, k_s = ids[sel], weights[sel], keys[sel]

    da = dyn_array.update_batch(CFG, dyn_array.init(CFG, K), k_s, ids_s, w_s)
    mle = np.asarray(dyn_array.estimate_mle_all(CFG, da))
    assert (mle[13:] == 0.0).all()
    mle_lut = np.asarray(dyn_array.estimate_mle_all(CFG, da, solver="lut"))
    assert (mle_lut[13:] == 0.0).all()

    # Straight through the layer: routed kind zeroes all-r_min rows exactly.
    regs = jnp.full((3, CFG.m), CFG.r_min, dtype=jnp.int8)
    for solver in ("newton", "lut", "fused"):
        if solver == "fused":
            chat = ops.estimate_rows_op(CFG, regs, kind="routed")[0]
        else:
            chat = estimation.estimate_rows(CFG, regs, kind="routed", solver=solver)
        assert (np.asarray(chat) == 0.0).all(), solver

    # Window + qsketch_dyn merge keep the guard through their union paths.
    wa = window_array.init(CFG, K, 4)
    wa = window_array.update_batch(CFG, wa, k_s, ids_s, w_s)
    win = np.asarray(window_array.estimate_window(CFG, wa, 4))
    assert (win[13:] == 0.0).all()
    empty = qsketch_dyn.init(CFG)
    merged = qsketch_dyn.merge(CFG, empty, empty)
    assert float(merged.chat) == 0.0


def test_routed_scaling_vs_full():
    """kind="routed" is m * the MLE of the routed likelihood (nonzero rows)."""
    cfg = SketchConfig(m=16, b=6)
    rng = np.random.default_rng(11)
    regs = jnp.asarray(
        rng.integers(cfg.r_min + 1, cfg.r_max, size=(4, cfg.m), dtype=np.int64).astype(np.int8)
    )
    hists = jax.vmap(lambda r: estimators.histogram(cfg, r))(regs)
    full = estimation.estimate_hists(cfg, hists, kind="full")
    routed = estimation.estimate_hists(cfg, hists, kind="routed")
    assert np.allclose(np.asarray(routed), np.asarray(full) * cfg.m, rtol=0, atol=0)


# ---------------------------------------------------------------------------
# dispatch validation
# ---------------------------------------------------------------------------


def test_bad_solver_and_kind_raise(states):
    sa = states[1]
    hists = sketch_array.histograms(CFG, sa)
    with pytest.raises(ValueError, match="solver"):
        estimation.estimate_hists(CFG, hists, solver="bogus")
    with pytest.raises(ValueError, match="kind"):
        estimation.estimate_hists(CFG, hists, kind="bogus")
    with pytest.raises(ValueError, match="fused"):
        estimation.estimate_hists(CFG, hists, solver="fused")
    with pytest.raises(ValueError, match="solver"):
        estimation.estimate_rows(CFG, sa.regs, solver="bogus")
