"""The paper's own configuration space: the sketch suites of §5.

These drive the accuracy/throughput benchmarks (paper Figs. 2-8) and the
framework's telemetry defaults. ``telemetry_default`` is the SketchConfig the
training/serving monitors use (m=512, b=8: ~4%% RRMSE, 512 B of registers;
the monitor does full m-wide QSketch updates in-step — see
sketchstream/monitor.py for why Dyn's O(1) route is not used there — so m
prices the per-step lane-op cost, and the cross-pod merge stays sub-KB).
"""

from repro.core import SketchConfig

# Paper defaults: 8-bit registers, r in [-127, 127] (Thm. 1 example).
REGISTER_SWEEP = tuple(2**k for k in range(6, 13))  # m in {64 .. 4096}
WIDTH_SWEEP = (4, 5, 6, 7, 8)  # register bits b (Fig. 5)


def suite(m: int = 256, b: int = 8, seed: int = 0x5EED) -> SketchConfig:
    return SketchConfig(m=m, b=b, seed=seed)


def telemetry_default() -> SketchConfig:
    return SketchConfig(m=512, b=8, seed=0xBEEF)
