"""SketchArray throughput: fused K-sketch update vs the naive K-loop.

The multi-tenant workload (K flows/users/experts, one keyed stream) has two
obvious schedules:

  * naive  — keep K ``QSketchState``s, partition each batch by key on the
             host, and dispatch one single-sketch ``qsketch.update`` per key
             (partitions padded to power-of-two buckets so jit compiles are
             amortized, same trick as benchmarks/throughput.py).
  * fused  — ONE ``sketch_array.update`` call: the whole keyed batch lands in
             the int8[K, m] register matrix via a segment scatter-max.

Both do identical sketch math (bit-identical states — asserted below), so the
gap is pure dispatch/launch overhead: the naive loop pays O(K) dispatches per
batch, the fused path pays one. The acceptance bar for this entry is >= 10x
at K=1024, m=256.

Also timed: ``estimate_all`` (one vmapped histogram-MLE for all K) vs a
Python loop of K single-sketch MLE calls.

``run_sharded`` extends the sweep past one host: the same keyed workload
into a mesh-sharded register matrix (core/sharded_array.py) across every
visible device, K up to 2^20 — update throughput, estimate_all latency, and
bit-identity between the two schedules.
"""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import (
    SketchArrayState,
    SketchConfig,
    key_directory,
    qsketch,
    sharded_array,
    sketch_array,
)

from . import common


def _keyed_batches(n_keys, n_batches, batch, seed=0):
    """Uniform keys: EVERY tenant is active each batch (the hard regime for
    the naive loop — a Zipf key draw would let it skip most of the K
    dispatches; real per-user monitoring at K=1e3+ looks uniform-ish within
    a batch window)."""
    rng = np.random.default_rng(seed)
    out = []
    for _ in range(n_batches):
        keys = rng.integers(0, n_keys, batch, dtype=np.int32)
        ids = rng.integers(0, 2**32, batch, dtype=np.uint32)
        w = (rng.gamma(1.0, 2.0, batch) + 1e-5).astype(np.float32)
        out.append((keys, ids, w))
    return out


def _measure_fused(cfg, n_keys, batches):
    st = sketch_array.init(cfg, n_keys)
    # Warm (compile + realistic register occupancy).
    st = sketch_array.update(
        cfg, st, jnp.asarray(batches[0][0]), jnp.asarray(batches[0][1]), jnp.asarray(batches[0][2])
    )
    jax.block_until_ready(st)
    t0 = time.perf_counter()
    n = 0
    for keys, ids, w in batches[1:]:
        st = sketch_array.update(cfg, st, jnp.asarray(keys), jnp.asarray(ids), jnp.asarray(w))
        n += len(ids)
    jax.block_until_ready(st)
    return n / (time.perf_counter() - t0), st


def _buckets(n):
    b = 16
    while b < n:
        b *= 2
    return b


def _measure_naive(cfg, n_keys, batches):
    states = [qsketch.init(cfg) for _ in range(n_keys)]
    # Pre-warm the power-of-two bucket shapes the partitions will hit.
    for b in (16, 32, 64, 128, 256, 512, 1024):
        _ = qsketch.update(cfg, states[0], jnp.zeros((b,), jnp.uint32), jnp.full((b,), 1e-30, jnp.float32))
    states = [qsketch.init(cfg) for _ in range(n_keys)]

    def one_batch(keys, ids, w):
        order = np.argsort(keys, kind="stable")
        keys_s, ids_s, w_s = keys[order], ids[order], w[order]
        bounds = np.searchsorted(keys_s, np.arange(n_keys + 1))
        for k in range(n_keys):
            lo, hi = bounds[k], bounds[k + 1]
            if lo == hi:
                continue
            bucket = _buckets(hi - lo)
            pad = bucket - (hi - lo)
            pk = np.pad(ids_s[lo:hi], (0, pad))
            pw = np.pad(w_s[lo:hi], (0, pad), constant_values=1e-30)
            states[k] = qsketch.update(cfg, states[k], jnp.asarray(pk), jnp.asarray(pw))

    one_batch(*batches[0])  # warm occupancy like the fused path
    jax.block_until_ready([s.regs for s in states])
    t0 = time.perf_counter()
    n = 0
    for keys, ids, w in batches[1:]:
        one_batch(keys, ids, w)
        n += len(ids)
    jax.block_until_ready([s.regs for s in states])
    return n / (time.perf_counter() - t0), states


def run(quick=True):
    n_keys, m, batch = 1024, 256, 8192
    n_batches = 4 if quick else 12
    cfg = SketchConfig(m=m, b=8, seed=5)
    batches = _keyed_batches(n_keys, n_batches, batch, seed=7)

    eps_fused, st_fused = _measure_fused(cfg, n_keys, batches)
    eps_naive, states_naive = _measure_naive(cfg, n_keys, batches)
    speedup = eps_fused / eps_naive

    # The two schedules must agree bitwise — weight 1e-30 pad rows quantize to
    # r_min (no-ops), so bucketing does not perturb the naive states.
    fused_np = np.asarray(st_fused.regs)
    naive_np = np.stack([np.asarray(s.regs) for s in states_naive])
    if not np.array_equal(fused_np, naive_np):
        raise AssertionError("fused and naive SketchArray schedules diverged")

    est_all_s = common.time_fn(
        lambda r: sketch_array.estimate_all(cfg, SketchArrayState(regs=r)), st_fused.regs
    )

    rows = [
        {
            "figure": "sketch_array_throughput",
            "method": "fused",
            "k": n_keys,
            "m": m,
            "mops": eps_fused / 1e6,
        },
        {
            "figure": "sketch_array_throughput",
            "method": "naive_loop",
            "k": n_keys,
            "m": m,
            "mops": eps_naive / 1e6,
        },
        {
            "figure": "sketch_array_throughput",
            "method": "speedup",
            "k": n_keys,
            "m": m,
            "x": speedup,
        },
        {
            "figure": "sketch_array_estimation",
            "method": "estimate_all(vmap)",
            "k": n_keys,
            "us": est_all_s * 1e6,
        },
    ]
    common.csv_row(f"sketch_array/K{n_keys}/m{m}/fused", 1e6 / eps_fused, f"mops={eps_fused/1e6:.3f}")
    common.csv_row(f"sketch_array/K{n_keys}/m{m}/naive", 1e6 / eps_naive, f"mops={eps_naive/1e6:.3f}")
    common.csv_row(
        f"sketch_array/K{n_keys}/m{m}/speedup", 0.0, f"fused/naive={speedup:.1f}x (>=10x required)"
    )
    common.csv_row(
        f"sketch_array/K{n_keys}/estimate_all", est_all_s * 1e6, "vmapped histogram-MLE, all K"
    )
    common.save("sketch_array", rows)
    return rows


# ---------------------------------------------------------------------------
# Sharded vs single-host scaling sweep (core/sharded_array.py)
# ---------------------------------------------------------------------------


def _tenant_batches(dcfg, n_batches, batch, seed=0):
    """Keyed batches carrying PRE-ROUTED slots (uniform over the sparse
    64-bit tenant space), so both schedules time pure sketch work."""
    rng = np.random.default_rng(seed)
    out = []
    for _ in range(n_batches):
        lo, hi = key_directory.split_uint64(rng.integers(0, 2**64, batch, dtype=np.uint64))
        slots = key_directory.route_slots(dcfg, (lo, hi))
        ids = jnp.asarray(rng.integers(0, 2**32, batch, dtype=np.uint32))
        w = jnp.asarray((rng.gamma(1.0, 2.0, batch) + 1e-5).astype(np.float32))
        out.append((slots, ids, w))
    return out


def _throughput(update_fn, state, batches):
    state = update_fn(state, *batches[0])  # warm: compile + occupancy
    jax.block_until_ready(jax.tree.leaves(state))
    t0 = time.perf_counter()
    n = 0
    for slots, ids, w in batches[1:]:
        state = update_fn(state, slots, ids, w)
        n += len(ids)
    jax.block_until_ready(jax.tree.leaves(state))
    return n / (time.perf_counter() - t0), state


def run_sharded(quick=True):
    """Sharded-vs-single-host SketchArray scaling: update throughput and
    estimate_all latency as K grows past one host's comfort zone.

    Uses every visible device as a shard of the ``sketch`` mesh axis (run
    under scripts/test.sh / XLA_FLAGS for the 8-device host mesh). The two
    schedules are bit-identical (asserted), so the deltas are pure routing +
    shard_map overhead vs the O(K) single-host register residency.
    """
    from repro.launch.mesh import make_sketch_mesh

    mesh = make_sketch_mesh()
    n_dev = sharded_array.num_shards(mesh)
    m, batch = 128, 8192
    n_batches = 4 if quick else 10
    ks = [4096, 65536] if quick else [4096, 65536, 1048576]

    rows = []
    for k in ks:
        cfg = SketchConfig(m=m, b=8, seed=17)
        dcfg = key_directory.DirectoryConfig(capacity=k, seed=23)
        batches = _tenant_batches(dcfg, n_batches, batch, seed=k)

        eps_single, st_single = _throughput(
            lambda s, sl, i, w: sketch_array.update(cfg, s, sl, i, w),
            sketch_array.init(cfg, k),
            batches,
        )
        eps_shard, st_shard = _throughput(
            lambda s, sl, i, w: sharded_array.update(cfg, mesh, s, sl, i, w),
            sharded_array.init(cfg, k, mesh),
            batches,
        )
        if not np.array_equal(np.asarray(st_shard.regs), np.asarray(st_single.regs)):
            raise AssertionError(f"sharded and single-host registers diverged at K={k}")

        est_single_s = common.time_fn(
            lambda r: sketch_array.estimate_all(cfg, SketchArrayState(regs=r)),
            st_single.regs, warmup=1, iters=3,
        )
        est_shard_s = common.time_fn(
            lambda r: sharded_array.estimate_all(
                cfg, mesh, sharded_array.ShardedArrayState(regs=r)
            ),
            st_shard.regs, warmup=1, iters=3,
        )

        for method, eps, est_s in (
            ("single_host", eps_single, est_single_s),
            (f"sharded_x{n_dev}", eps_shard, est_shard_s),
        ):
            rows.append(
                {
                    "figure": "sketch_array_sharded_scaling",
                    "method": method,
                    "k": k,
                    "m": m,
                    "shards": 1 if method == "single_host" else n_dev,
                    "update_mops": eps / 1e6,
                    "estimate_all_ms": est_s * 1e3,
                }
            )
            common.csv_row(
                f"sketch_array_sharded/K{k}/{method}",
                1e6 / eps,
                f"update={eps / 1e6:.3f}Mops estimate_all={est_s * 1e3:.1f}ms",
            )
        common.csv_row(
            f"sketch_array_sharded/K{k}/estimate_speedup",
            0.0,
            f"single/sharded={est_single_s / max(est_shard_s, 1e-12):.2f}x on {n_dev} shards",
        )
    common.save("sketch_array_sharded", rows)
    return rows
