"""layering — the raw Newton solver is reachable only via core/estimation.py.

``estimators.qsketch_mle`` is the bit-identity reference solver; calling it
directly bypasses the estimation layer's solver registry, the routed x*m
scaling, and the untouched-row guard (DESIGN.md §8.7). The old tier-2 grep
enforced this textually over ``core/`` + ``sketchstream/`` only — it could
not cover ``kernels/`` (docstrings there mention the symbol), could not see
through ``from ... import ... as`` renames at the *use* site, and matched
comments. This rule resolves uses through the import/alias graph instead:

* ``from repro.core.estimators import qsketch_mle as f`` — the binding and
  every later ``f(...)`` use are findings,
* ``from repro.core import estimators as e`` + ``e.qsketch_mle`` — finding,
* local aliases (``solve = estimators.qsketch_mle``) — finding at each use,
* ``getattr(estimators, "qsketch_mle")`` — finding,

anywhere in the analysis scope except the estimation layer itself
(``core/estimation.py`` and the defining ``core/estimators.py``).
"""

from __future__ import annotations

import ast

from repro.analysis.astutil import ImportMap, dotted
from repro.analysis.findings import Finding
from repro.analysis.registry import Rule, register

TARGET = "repro.core.estimators.qsketch_mle"
SYMBOL = "qsketch_mle"
ALLOWED = ("src/repro/core/estimation.py", "src/repro/core/estimators.py")


def _is_target(qual: str | None) -> bool:
    return qual is not None and (
        qual == TARGET or qual.endswith(".estimators." + SYMBOL)
    )


@register
class LayeringRule(Rule):
    """Flag any resolved reference to ``estimators.qsketch_mle`` outside the
    estimation layer."""

    name = "layering"
    description = (
        "estimators.qsketch_mle may only be referenced from core/estimation.py "
        "(solver registry, routed scaling, untouched-row guard)"
    )

    def run(self, ctx) -> list[Finding]:
        """Run the rule over the context's selected modules."""
        findings: list[Finding] = []
        for mod in ctx.iter_modules():
            if mod.rel in ALLOWED or not ctx.is_selected(mod.rel):
                continue
            imap = ImportMap(mod.tree, mod.name)
            # Direct from-imports of the symbol are findings at the binding.
            for node in ast.walk(mod.tree):
                if isinstance(node, ast.ImportFrom):
                    for alias in node.names:
                        if alias.name == SYMBOL and _is_target(
                            imap.names.get(alias.asname or alias.name)
                        ):
                            findings.append(
                                Finding(
                                    self.name,
                                    mod.rel,
                                    node.lineno,
                                    f"imports estimators.{SYMBOL}"
                                    + (f" as '{alias.asname}'" if alias.asname else ""),
                                )
                            )
                elif isinstance(node, (ast.Name, ast.Attribute)):
                    if not isinstance(node.ctx, ast.Load):
                        continue
                    d = dotted(node)
                    if d is None:
                        continue
                    if _is_target(imap.resolve(node)):
                        findings.append(
                            Finding(
                                self.name,
                                mod.rel,
                                node.lineno,
                                f"references estimators.{SYMBOL} via '{d}' — "
                                "route through core/estimation.py",
                            )
                        )
                elif isinstance(node, ast.Call):
                    # getattr(<estimators module>, "qsketch_mle")
                    if (
                        isinstance(node.func, ast.Name)
                        and node.func.id == "getattr"
                        and len(node.args) >= 2
                        and isinstance(node.args[1], ast.Constant)
                        and node.args[1].value == SYMBOL
                    ):
                        base = imap.resolve(node.args[0])
                        if base is not None and base.endswith("estimators"):
                            findings.append(
                                Finding(
                                    self.name,
                                    mod.rel,
                                    node.lineno,
                                    f"getattr access to estimators.{SYMBOL} — "
                                    "route through core/estimation.py",
                                )
                            )
        return findings
