"""Weighted-cardinality estimators.

Implements the paper's estimators in histogram form:

* ``lm_estimate``    — Eq. (2): (m-1) / sum(R) for float min-sketches.
* ``qsketch_init``   — the Newton seed Ĉ0 = (m-1) / Σ 2^{-R[j]}.
* ``qsketch_mle``    — §4.2 MLE via Newton–Raphson on the truncated quantized
                       likelihood, solved with ``lax.while_loop``.
* ``mle_numpy``      — float64 numpy oracle used by tests/benchmarks.

Beyond-paper optimization (DESIGN.md §8.3): the likelihood only depends on the
*histogram* of register values (≤ 2^b bins), so estimation is O(2^b) + O(m)
for the bincount, not O(m · iters). The paper uses the histogram trick only
for Dyn's q_R; applying it to the MLE makes anytime estimation cheap enough
to run inside a training step.

Numerics (f32-safe for TPU, DESIGN.md §4.4): with s = 2^{-(R+1)} the interior
bin term of f(C) = d/dC log L is

    t(C) = s * (2 - e^{Cs}) / (e^{Cs} - 1)  =  s / expm1(Cs) - s,

and its derivative  t'(C) = -s^2 e^{Cs} / expm1(Cs)^2.  For Cs -> 0 these
limit to 1/C - 3s/2 and -1/C^2; we switch to the series below z=1e-4 to avoid
subnormal s^2 underflow at the r_max end (s down to 2^-128).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from .types import SketchConfig

_EPS_Z = 1e-4  # series-switch threshold for z = C*s


def lm_estimate(regs: jnp.ndarray) -> jnp.ndarray:
    """Unbiased estimator for LM/FastGM/FastExp float min-registers (Eq. 2).

    Contract: a register still at its init sentinel (f32-max / +inf) means
    "no element ever touched this register". If NO register was touched the
    stream is empty and the estimate is exactly 0.0; with sum(regs) at
    f32-max scale the division would otherwise return a tiny-but-nonzero
    garbage value (or 0/inf by accident of m). Partially-touched sketches
    still estimate through Eq. 2 — its variance already prices registers
    that happen to be large.
    """
    m = regs.shape[0]
    untouched = jnp.min(regs) >= jnp.float32(jnp.finfo(jnp.float32).max)
    # qlint: disable=int8-overflow (LM min-registers are f32 by design, not int8)
    est = (m - 1) / jnp.sum(regs)
    return jnp.where(untouched, jnp.float32(0.0), est)


def histogram(cfg: SketchConfig, regs: jnp.ndarray) -> jnp.ndarray:
    """Register-value histogram T with 2^b bins; bin k counts value k+r_min."""
    idx = regs.astype(jnp.int32) - cfg.r_min
    return jnp.zeros((cfg.num_bins,), jnp.int32).at[idx].add(1)


def _bin_scales(cfg: SketchConfig) -> np.ndarray:
    """s_k = 2^{-(k + r_min + 1)} for k = 0..2^b-1, as float32-exact values."""
    ks = np.arange(cfg.num_bins, dtype=np.float64) + cfg.r_min + 1.0
    return np.exp2(-ks).astype(np.float32)


def qsketch_init(cfg: SketchConfig, hist: jnp.ndarray) -> jnp.ndarray:
    """Newton seed Ĉ0 = (m-1) / Σ_j 2^{-R[j]}  (histogram form)."""
    s = jnp.asarray(_bin_scales(cfg))  # 2^{-(k+r_min+1)}
    denom = jnp.sum(hist.astype(jnp.float32) * s * 2.0)  # 2s = 2^{-(k+r_min)}
    return (cfg.m - 1) / jnp.maximum(denom, jnp.float32(1e-38))


def _f_and_fprime(cfg: SketchConfig, hist, c, s):
    """Score f(C) and derivative f'(C) of the truncated quantized likelihood.

    Bin 0 (value r_min) is the "saturated low" bin: log P = -C*2^{-(r_min+1)},
    contributing a constant -s_0 to f and 0 to f'. The top bin (value r_max)
    has P = 1 - e^{-C*2^{-r_max}}, contributing a/expm1(C*a) with a=2^{-r_max}
    (same algebraic form as the interior term's first piece).

    ``s`` carries the per-bin scales 2^{-(k+r_min+1)} — possibly *rebased* by
    an integer shift Δ (see ``qsketch_mle``): the likelihood is invariant
    under (R -> R-Δ, C -> C·2^Δ), which is how the solve stays in f32's
    comfortable range for C anywhere in the Thm.-1 span of ~10^±36.
    """
    nb = cfg.num_bins
    t = hist.astype(jnp.float32)

    def f_term(scale, zmin):
        """scale/expm1(C*scale) with small-z series; finite for all z>=0."""
        z = c * scale
        zz = jnp.clip(z, _EPS_Z, 88.0)  # expm1(88) < f32 max
        return jnp.where(z < _EPS_Z, 1.0 / c - zmin * scale, scale / jnp.expm1(zz))

    def fp_term(scale):
        """-(scale^2 e^z)/expm1(z)^2 = -(scale / (2 sinh(z/2)))^2, in log space.

        Log-space keeps the expression finite across the full dynamic range
        (scale spans 2^-128 .. 2^126; z spans underflow .. overflow). Bins in
        the overflow regime carry T=0 in any reachable state, but they must
        still evaluate to a finite number or 0 * nan poisons the sum.
        """
        z = c * scale
        zz = jnp.maximum(z, _EPS_Z)
        lsh = jnp.where(zz > 40.0, zz / 2.0, jnp.log(2.0 * jnp.sinh(jnp.minimum(zz, 40.0) / 2.0)))
        return jnp.where(z < _EPS_Z, -1.0 / (c * c), -jnp.exp(2.0 * (jnp.log(scale) - lsh)))

    # Interior bins: f = s/expm1(Cs) - s  (series: (1/C - 0.5s) - s = 1/C - 1.5s).
    f_int = f_term(s, 0.5) - s
    fp_int = fp_term(s)

    # Top OCCUPIED bin is r_max at index top = 2^b - 2 (the symmetric
    # truncation leaves the last int8 code point unused): a = 2^{-r_max}
    # = 2*s[top]; f = a/expm1(Ca). Bin 2^b-1 can never hold mass; its
    # interior-form terms are finite and multiplied by T=0.
    top = cfg.top_bin
    a = 2.0 * s[top]
    f_top = f_term(a, 0.5)
    fp_top = fp_term(a)

    # Bottom bin (r_min): log P linear in C -> constant slope.
    f_bot = -s[0]
    fp_bot = jnp.float32(0.0)

    f_terms = f_int.at[0].set(f_bot).at[top].set(f_top)
    fp_terms = fp_int.at[0].set(fp_bot).at[top].set(fp_top)
    f = jnp.sum(t * f_terms)
    fp = jnp.sum(t * fp_terms)
    return f, fp


@functools.partial(jax.jit, static_argnums=(0,))
def qsketch_mle(cfg: SketchConfig, hist: jnp.ndarray, max_iters: int = 60, tol: float = 1e-6):
    """MLE Ĉ from the register histogram via safeguarded Newton–Raphson.

    The solve is *rebased*: with Δ = round(mean register value), the invariance
    (R -> R-Δ, C -> C·2^Δ) of the likelihood lets Newton run on C' = C·2^{-Δ}
    which is O(1) for any reachable sketch — f32-safe even though C itself can
    span 10^±36 (f'(C) ~ -m/C^2 would under/overflow f32 otherwise; see
    tests/test_estimators.py::test_extreme_magnitudes).

    Returns (chat, stddev, converged):
      chat      — the ML estimate (float32);
      stddev    — Cramér–Rao proxy sqrt(-1/f'(Ĉ)) (paper §4.2);
      converged — False in the degenerate all-r_min / all-r_max cases (paper:
                  likelihood monotone, no interior extremum), where chat falls
                  back to 0 / the seed estimator.
    """
    m = cfg.m
    t = hist
    degenerate = (t[0] == m) | (t[cfg.top_bin] == m)

    kval = jnp.arange(cfg.num_bins, dtype=jnp.float32) + float(cfg.r_min)
    delta = jnp.round(jnp.sum(t.astype(jnp.float32) * kval) / m)
    # Rebased scales; exponent clamped to keep impossible far bins finite
    # (their T is 0 in any reachable state — they only need to not be inf).
    expo = jnp.clip(delta - (kval + 1.0), -126.0, 126.0)
    s = jnp.exp2(expo)

    # Seed in the rebased domain: Ĉ0' = (m-1)/Σ T_k 2^{-(k+r_min-Δ)}.
    c0 = (m - 1) / jnp.maximum(jnp.sum(t.astype(jnp.float32) * s * 2.0), jnp.float32(1e-30))
    c0 = jnp.clip(c0, jnp.float32(1e-20), jnp.float32(1e20))

    def cond(state):
        i, c, done = state
        return (~done) & (i < max_iters)

    def body(state):
        i, c, _ = state
        f, fp = _f_and_fprime(cfg, t, c, s)
        step = f / jnp.where(jnp.abs(fp) > 0, fp, jnp.float32(-1e-30))
        c_new = c - step
        # Safeguard: stay positive, limit per-step movement to 8x.
        c_new = jnp.clip(c_new, c / 8.0, c * 8.0)
        c_new = jnp.maximum(c_new, jnp.float32(1e-30))
        done = jnp.abs(c_new - c) <= tol * c
        return i + 1, c_new, done

    _, cprime, _ = jax.lax.while_loop(cond, body, (jnp.int32(0), c0, degenerate))
    _, fp = _f_and_fprime(cfg, t, cprime, s)
    std_prime = jnp.sqrt(jnp.maximum(-1.0 / jnp.where(jnp.abs(fp) > 0, fp, jnp.float32(-1e-30)), 0.0))
    scale_back = jnp.exp2(delta)
    chat = cprime * scale_back
    stddev = std_prime * scale_back
    chat = jnp.where(degenerate, jnp.where(t[0] == m, jnp.float32(0.0), chat), chat)
    return chat, stddev, ~degenerate


# ---------------------------------------------------------------------------
# float64 numpy oracle (tests + accuracy benchmarks)
# ---------------------------------------------------------------------------


def mle_numpy(cfg: SketchConfig, regs: np.ndarray, max_iters: int = 200, tol: float = 1e-12) -> float:
    """Reference float64 MLE identical in form to ``qsketch_mle``."""
    regs = np.asarray(regs, dtype=np.int64)
    hist = np.bincount(regs - cfg.r_min, minlength=cfg.num_bins).astype(np.float64)
    nb = cfg.num_bins
    ks = np.arange(nb, dtype=np.float64) + cfg.r_min + 1.0
    s = np.exp2(-ks)

    top = cfg.top_bin
    if hist[0] == cfg.m:
        return 0.0
    denom = float(np.sum(hist * s * 2.0))
    c = max((cfg.m - 1) / denom, 1e-300)
    if hist[top] == cfg.m:
        return c  # degenerate-high: fall back to seed

    def f_fp(c):
        z = c * s
        with np.errstate(over="ignore", under="ignore", divide="ignore", invalid="ignore"):
            zz = np.clip(z, 1e-12, 700.0)  # expm1(700) < f64 max
            em1 = np.expm1(zz)
            f_terms = np.where(z < 1e-12, 1.0 / c - 1.5 * s, s / em1 - s)
            # -(s / (2 sinh(z/2)))^2 in log space to stay finite everywhere.
            lsh = np.where(zz > 40.0, zz / 2.0, np.log(2.0 * np.sinh(np.minimum(zz, 40.0) / 2.0)))
            lz = np.maximum(c * s, 1e-300)  # true z for the z/2 asymptote
            lsh = np.where(lz > 700.0, lz / 2.0, lsh)
            fp_terms = np.where(z < 1e-12, -1.0 / c**2, -np.exp(2.0 * (np.log(s) - lsh)))
            a = 2.0 * s[top]
            za = np.clip(c * a, 1e-12, 700.0)
            f_terms[top] = 1.0 / c - 0.5 * a if c * a < 1e-12 else a / np.expm1(za)
            lsha = za / 2.0 if za > 40.0 else np.log(2.0 * np.sinh(za / 2.0))
            fp_terms[top] = -1.0 / c**2 if c * a < 1e-12 else -np.exp(2.0 * (np.log(a) - lsha))
            f_terms[0] = -s[0]
            fp_terms[0] = 0.0
        return float(np.sum(hist * f_terms)), float(np.sum(hist * fp_terms))

    for _ in range(max_iters):
        f, fp = f_fp(c)
        if fp == 0.0:
            break
        c_new = float(np.clip(c - f / fp, c / 8.0, c * 8.0))
        c_new = max(c_new, 1e-300)
        if abs(c_new - c) <= tol * c:
            c = c_new
            break
        c = c_new
    return c
