"""AnomalyBank: per-tenant drift scoring over windowed sketch estimates.

The paper motivates QSketch with "real-time applications like anomaly
detection"; this module is that last mile. It consumes the per-tenant
weighted-cardinality vector a ``WindowMonitor`` emits every step (the O(K)
anytime full-ring read, or a windowed MLE read) and maintains, per tenant:

* an **EWMA baseline** of the estimate and of its absolute deviation — the
  tenant's "normal" windowed traffic and its noise scale (sketch noise +
  genuine variation, no distributional assumption);
* a **one-sided CUSUM** drift score over the standardized residual
  s_t = max(0, s_{t-1} + z_t - k): small persistent drifts accumulate,
  zero-mean noise does not (Page's classic sequential test — the right shape
  for "this tenant's distinct weighted traffic is climbing", which a plain
  threshold on z misses and a threshold on the raw estimate can't normalize
  across tenants whose baselines differ by orders of magnitude).

``step`` is one fused jit over all K tenants — scoring a million tenants
costs a handful of O(K) vector ops, in the same spirit as the DynArray's
O(K) estimate read. Alerting semantics:

* warmup: the first ``warmup`` steps only adapt the baseline (running mean,
  not EWMA, so early baselines converge fast) and never score — a fresh bank
  doesn't alarm on the first batch it ever sees;
* gating: tenants whose baseline weight is below ``min_weight`` never score
  (empty slots of an over-provisioned K and dust-traffic tenants produce
  near-zero, MLE-noise-dominated estimates — DESIGN.md §8.5);
* damping: while a tenant's score exceeds the alert threshold ``h``, its
  baseline adapts at ``alpha * freeze_factor`` — slow enough that a
  sustained attack is not absorbed into "normal" within a few steps (which
  would self-clear the alert while the anomaly is live), but nonzero so a
  level shift that IS the new normal eventually re-baselines and the score
  drains, instead of ratcheting forever off a frozen baseline.

``top_alerts`` ranks the alerting tenants by score for human consumption —
the "ranked alert set" a pager wants.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class AnomalyConfig:
    """Frozen (hashable) scoring config — a valid ``jax.jit`` static arg.

    Attributes:
      alpha: EWMA step for the baseline mean/deviation (post-warmup).
      cusum_k: CUSUM slack in deviation units — drifts below k·dev are
        treated as noise and decay out of the score.
      cusum_h: alert threshold in slack-adjusted deviation units; a tenant
        alerts while score > cusum_h.
      warmup: steps of baseline-only adaptation before scoring starts. For
        sliding-window feeds, cover the ring fill (warmup >= E): while the
        ring fills, EVERY tenant's windowed estimate drifts up as the window
        widens, which is growth of the window, not of the tenant.
      min_weight: baseline gate — tenants whose EWMA baseline is below this
        never score (kills empty-slot / dust-tenant noise).
      min_scale: absolute floor on the deviation scale (a tenant with a
        perfectly flat history must not alert on f32 dust).
      freeze_factor: baseline-adaptation multiplier while over threshold, in
        [0, 1); see "damping" in the module docstring.
    """

    alpha: float = 0.2
    cusum_k: float = 0.5
    cusum_h: float = 6.0
    warmup: int = 3
    min_weight: float = 1.0
    min_scale: float = 1e-3
    freeze_factor: float = 0.1

    def __post_init__(self):
        if not 0.0 < self.alpha <= 1.0:
            raise ValueError("EWMA alpha must be in (0, 1]")
        if self.cusum_h <= 0 or self.cusum_k < 0:
            raise ValueError("need cusum_h > 0 and cusum_k >= 0")
        if self.warmup < 1:
            raise ValueError("warmup must be >= 1 (the first observation has no baseline)")
        if not 0.0 <= self.freeze_factor < 1.0:
            raise ValueError("freeze_factor must be in [0, 1)")


class AnomalyBankState(NamedTuple):
    """Per-tenant scoring state (a pytree; threads through jit/scan/ckpt)."""

    mean: jnp.ndarray  # f32[K] EWMA baseline of the windowed estimate
    dev: jnp.ndarray  # f32[K] EWMA of |residual| (noise scale)
    score: jnp.ndarray  # f32[K] one-sided CUSUM drift score
    n_steps: jnp.ndarray  # int32 scalar, observations folded so far


def init(k: int) -> AnomalyBankState:
    """Fresh bank for K tenants: zero baselines/deviations/scores."""
    if k < 1:
        raise ValueError("AnomalyBank needs k >= 1 tenants")
    return AnomalyBankState(
        mean=jnp.zeros((k,), jnp.float32),
        dev=jnp.zeros((k,), jnp.float32),
        score=jnp.zeros((k,), jnp.float32),
        n_steps=jnp.int32(0),
    )


@functools.partial(jax.jit, static_argnums=(0,))
def step(bcfg: AnomalyConfig, state: AnomalyBankState, estimates) -> tuple[
    AnomalyBankState, jnp.ndarray
]:
    """Fold one observation vector Ĉ[K]; -> (state', scores f32[K]).

    Scores are in threshold units: score > cusum_h  ⇔  alerting. During
    warmup every score is 0 and the baseline adapts as a running mean (step
    t weights the new observation 1/(t+1)); afterwards mean/dev follow the
    EWMA except for tenants over threshold AFTER this step's scoring, whose
    adaptation is damped to ``freeze_factor * alpha`` until the score drains
    back under the threshold (see "damping" in the module docstring).
    """
    est = jnp.asarray(estimates, jnp.float32)
    in_warmup = state.n_steps < bcfg.warmup

    resid = est - state.mean
    scale = jnp.maximum(state.dev, bcfg.min_scale)
    z = resid / scale
    scored = (
        (~in_warmup)
        & (state.mean >= bcfg.min_weight)
    )
    score = jnp.where(
        scored, jnp.maximum(0.0, state.score + z - bcfg.cusum_k), 0.0
    )

    # Baseline adaptation: running mean during warmup, EWMA after, damped by
    # freeze_factor while alerting — gated on the score JUST computed, so the
    # step that crosses the threshold is already damped.
    eff_alpha = jnp.where(
        in_warmup,
        1.0 / (state.n_steps.astype(jnp.float32) + 1.0),
        jnp.float32(bcfg.alpha),
    )
    adapt = jnp.where(score > bcfg.cusum_h, bcfg.freeze_factor * eff_alpha, eff_alpha)
    mean = state.mean + adapt * resid
    dev = state.dev + adapt * (jnp.abs(resid) - state.dev)

    return (
        AnomalyBankState(
            mean=mean, dev=dev, score=score, n_steps=state.n_steps + 1
        ),
        score,
    )


def merge(a: AnomalyBankState, b: AnomalyBankState) -> AnomalyBankState:
    """Cross-pod telemetry union for banks scoring DISJOINT tenant rows
    (key-partitioned fleets): element-wise sum of baselines/scores is exact
    when each tenant is live on exactly one pod (the other pod holds zeros).
    Banks that scored the same tenant must not be merged — re-score from the
    merged monitor instead.
    """
    if a.mean.shape != b.mean.shape:
        raise ValueError(
            f"AnomalyBank merge needs matching K, got {a.mean.shape} vs {b.mean.shape}"
        )
    return AnomalyBankState(
        mean=a.mean + b.mean,
        dev=a.dev + b.dev,
        score=a.score + b.score,
        n_steps=jnp.maximum(a.n_steps, b.n_steps),
    )


def top_alerts(bcfg: AnomalyConfig, scores, n: int = 5):
    """Host-side ranked alert set: [(slot, score), ...] for the up-to-n
    tenants whose score exceeds the threshold, strongest first."""
    s = np.asarray(scores)
    over = np.nonzero(s > bcfg.cusum_h)[0]
    ranked = over[np.argsort(-s[over], kind="stable")][: int(n)]
    return [(int(i), float(s[i])) for i in ranked]
