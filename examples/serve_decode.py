"""Serving example: batched prefill + decode with weighted-DAU telemetry.

Generates from a (smoke-sized) qwen3-8b with a per-session engagement
weight; the decode loop's QSketch monitor answers "weighted distinct
sessions served" at any time — the paper's motivating DAU metric — without
storing any session log.

    PYTHONPATH=src python examples/serve_decode.py
"""

from repro.launch import serve


def main():
    serve.main([
        "--arch", "qwen3-8b", "--smoke",
        "--batch", "4", "--prompt-len", "12", "--gen", "16", "--max-len", "48",
        "--temperature", "0.8",
    ])


if __name__ == "__main__":
    main()
