"""The Finding record qlint rules emit, and its stable baseline key.

A finding pins (rule, repo-relative path, 1-based line, message). The
baseline key deliberately EXCLUDES the line number — grandfathered findings
must survive unrelated edits above them — so rule messages must themselves
be stable (symbol names, not line numbers or column offsets, in the text).
"""

from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True, order=True)
class Finding:
    """One rule violation at a source location.

    ``path`` is repo-relative with forward slashes; ``message`` must be
    deterministic and line-number-free (it is part of the baseline key).
    """

    rule: str
    path: str
    line: int
    message: str

    @property
    def key(self) -> str:
        """Stable identity used by the baseline file: rule::path::message."""
        return f"{self.rule}::{self.path}::{self.message}"

    def format(self) -> str:
        """Human-readable one-liner: ``path:line: [rule] message``."""
        return f"{self.path}:{self.line}: [{self.rule}] {self.message}"
