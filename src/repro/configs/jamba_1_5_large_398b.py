"""jamba-1.5-large-398b [hybrid] — Mamba+attn 1:7 interleave, MoE 16e top-2.

72L d_model=8192 64H (GQA kv=8) d_ff=24576 vocab=65536 [arXiv:2403.19887; hf].
Superblock of 8: attention at index 4, Mamba elsewhere; MoE every other layer
(4 MoE + 4 dense FFN per superblock), matching the published 1:7 ratio and
e=2 MoE stride. Hybrid -> runs long_500k (attn KV is 9 layers only).
"""

from repro.models import LayerSpec, MoEConfig, ModelConfig, SSMConfig


def build() -> ModelConfig:
    pattern = tuple(
        LayerSpec(
            mixer="attn" if i == 4 else "mamba",
            ffn="moe" if i % 2 == 1 else "dense",
        )
        for i in range(8)
    )
    return ModelConfig(
        name="jamba-1.5-large-398b",
        n_layers=72,
        d_model=8192,
        n_heads=64,
        n_kv_heads=8,
        d_ff=24576,
        vocab=65536,
        pattern=pattern,
        moe=MoEConfig(num_experts=16, top_k=2),
        ssm=SSMConfig(d_state=128, head_dim=128, expand=2, chunk=256),
        rope_theta=1_000_000.0,
        max_seq=262_144,
        sub_quadratic=True,
    )
