"""Shared pytest fixtures.

The suite compiles several hundred distinct XLA programs (every container
x solver x mesh combination is jitted). On the CPU backend that much
accumulated compile state has crashed the compiler mid-suite — a native
segfault in a late module's first `pjit` cache miss that no single module
reproduces in isolation. Dropping the caches at module boundaries keeps
each module's compile session small; the only cost is re-tracing shared
helpers, which is noise next to the solves themselves.
"""

import jax
import pytest


@pytest.fixture(autouse=True, scope="module")
def _fresh_compile_caches_per_module():
    yield
    jax.clear_caches()
