"""Paper Figs. 6-8: update throughput and estimation time.

Update throughput (Mops = million stream elements/second) is measured on a
Zipf-repeated stream (heavy duplicates, as in the paper's real datasets) fed
in fixed-size batches through jitted updates:

  * LM            — full m-wide work per element (Alg. 1), fused kernel path
  * FastGM/FastExp— order-statistics schedule + batch-prune COMPACTION: the
                    one-hash prune test runs on-device, survivors are
                    host-compacted into power-of-two buckets (static shapes
                    -> no recompile), and only survivors pay the m-wide
                    generation. This is the paper's early-stop, SIMD form
                    (DESIGN.md §4.1).
  * QSketch       — same two variants (direct / pruned+compacted)
  * QSketch-Dyn   — one register per element (Alg. 3 batch mode)

CPU caveat (stated in EXPERIMENTS.md): these are CPU-JAX numbers — the
*ordering* and *scaling in m* are the reproducible claims; absolute Mops on
TPU come from the kernel roofline, not this box.

Estimation time compares O(m) (Eq. 2 sum) vs the histogram MLE
(O(m) bincount + O(2^b) Newton) vs QSketch-Dyn's O(1) running estimate.
"""

from __future__ import annotations

import numpy as np
import jax
import jax.numpy as jnp

from repro.core import METHODS, SketchConfig, baselines, estimators, qsketch, qsketch_dyn
from repro.data import synthetic

from . import common

_BATCH = 32768


def _buckets(n):
    """Power-of-two compaction buckets (static shapes, no recompile)."""
    b = 1
    while b < n:
        b *= 2
    return b


def _stream_batches(n_stream, seed=0):
    ids, w, _ = synthetic.with_repeats("gamma", max(n_stream // 8, 1000), n_stream, seed=seed)
    return [
        (ids[i : i + _BATCH], w[i : i + _BATCH])
        for i in range(0, n_stream - _BATCH + 1, _BATCH)
    ]


def measure_method(name: str, cfg: SketchConfig, batches, pruned: bool = False):
    """Returns {"eps", "batch_lat_us"[, "survivor_frac"]}. Warm sketch first
    so prune rates are realistic.

    Two numbers per method, measured separately because they answer
    different questions (and were conflated before):

    * ``eps`` — SUSTAINED elements/s: the update loop runs with device
      dispatch left asynchronous and ``block_until_ready`` only at the sweep
      boundary, so it measures pipeline throughput — directly comparable to
      the ingest bench's ``sustained_mops`` (benchmarks/ingest.py).
    * ``batch_lat_us`` — per-batch LATENCY: a short pass that blocks after
      every update, the synchronous cost a blocking caller pays per batch.
    """
    meth = METHODS[name]
    st = meth["init"](cfg)
    upd = meth["update"]
    # Warm: fill the sketch + trigger compiles.
    for ids, w in batches[:2]:
        st = upd(cfg, st, jnp.asarray(ids), jnp.asarray(w))
    jax.block_until_ready(st)

    if not pruned:
        import time

        # Latency pass: per-batch blocking over a small prefix.
        lat = []
        for ids, w in batches[2 : 2 + min(4, len(batches) - 2)]:
            t0 = time.perf_counter()
            st = upd(cfg, st, jnp.asarray(ids), jnp.asarray(w))
            jax.block_until_ready(st)
            lat.append(time.perf_counter() - t0)

        # Sustained pass: async dispatch, one block at the sweep boundary.
        t0 = time.perf_counter()
        n = 0
        for ids, w in batches[2:]:
            st = upd(cfg, st, jnp.asarray(ids), jnp.asarray(w))
            n += len(ids)
        jax.block_until_ready(st)
        return {
            "eps": n / (time.perf_counter() - t0),
            "batch_lat_us": float(np.mean(lat)) * 1e6,
        }

    # Pruned path: on-device survival mask, host compaction, bucketed update.
    prune = qsketch.prune_mask if name == "QSketch" else baselines.fastgm_prune_mask
    upd_p = qsketch.update_pruned if name == "QSketch" else upd
    import time

    # Pre-warm every bucket size so jit compiles don't pollute the timing
    # (each power-of-two survivor bucket is a distinct static shape).
    b = 16
    while b <= _BATCH:
        wa = jnp.ones((b,), jnp.float32)
        ia = jnp.zeros((b,), jnp.uint32)
        st = upd_p(cfg, st, ia, wa * 1e-30)
        b *= 2
    _ = np.asarray(prune(cfg, st, jnp.asarray(batches[0][0]), jnp.asarray(batches[0][1])))

    t0 = time.perf_counter()
    n = 0
    survivors = 0
    lat = []
    for ids, w in batches[2:]:
        tb = time.perf_counter()
        mask = np.asarray(prune(cfg, st, jnp.asarray(ids), jnp.asarray(w)))
        n += len(ids)
        sids, sw = ids[mask], w[mask]
        survivors += len(sids)
        if len(sids):
            bucket = max(_buckets(len(sids)), 16)
            pad = bucket - len(sids)
            sids = np.pad(sids, (0, pad))
            sw = np.pad(sw, (0, pad), constant_values=1e-30)  # ~no-op weight
            st = upd_p(cfg, st, jnp.asarray(sids), jnp.asarray(sw))
        # The prune test host-syncs per batch by construction (the mask
        # drives host compaction), so sustained == sum of per-batch times
        # here; latency is still reported for comparability.
        lat.append(time.perf_counter() - tb)
    jax.block_until_ready(st)
    return {
        "eps": n / (time.perf_counter() - t0),
        "batch_lat_us": float(np.mean(lat)) * 1e6,
        "survivor_frac": survivors / max(n, 1),
    }


def run_update_throughput(quick=True):
    n_stream = 2 * _BATCH * (4 if quick else 16) + _BATCH
    ms = [256, 1024] if quick else [256, 1024, 4096]
    batches = _stream_batches(n_stream)
    rows = []
    for m in ms:
        cfg = SketchConfig(m=m, b=8, seed=3)
        for name in METHODS:
            r = measure_method(name, cfg, batches)
            eps = r["eps"]
            rows.append({"figure": "fig6_7_throughput", "method": name, "m": m,
                         "pruned": False, "mops": eps / 1e6,
                         "sustained_mops": eps / 1e6,
                         "batch_latency_us": r["batch_lat_us"]})
            common.csv_row(
                f"throughput/m{m}/{name}", 1e6 / eps,
                f"sustained_mops={eps/1e6:.3f} batch_lat_us={r['batch_lat_us']:.0f}",
            )
        for name in ("QSketch", "FastGM"):
            r = measure_method(name, cfg, batches, pruned=True)
            eps, surv = r["eps"], r["survivor_frac"]
            rows.append({"figure": "fig6_7_throughput", "method": name + "+prune", "m": m,
                         "pruned": True, "mops": eps / 1e6,
                         "sustained_mops": eps / 1e6,
                         "batch_latency_us": r["batch_lat_us"],
                         "survivor_frac": surv})
            common.csv_row(
                f"throughput/m{m}/{name}+prune", 1e6 / eps,
                f"sustained_mops={eps/1e6:.3f} batch_lat_us={r['batch_lat_us']:.0f} "
                f"survivors={surv:.3%} (work-saving of the early stop)",
            )
    return rows


def run_estimation_time(quick=True):
    ms = [1024, 16384] if quick else [1024, 16384, 262144, 1048576]
    rows = []
    for m in ms:
        cfg = SketchConfig(m=m, b=8, seed=4)
        ids, w, _ = synthetic.stream("gamma", 5000, seed=1)
        stq = qsketch.update(cfg, qsketch.init(cfg), jnp.asarray(ids), jnp.asarray(w))
        stl = baselines.lm_update(cfg, baselines.init(cfg), jnp.asarray(ids), jnp.asarray(w))

        t_lm = common.time_fn(jax.jit(lambda r: (m - 1) / jnp.sum(r)), stl.regs)
        t_q = common.time_fn(lambda r: qsketch.estimate(cfg, type(stq)(r)), stq.regs)
        rows.append({"figure": "fig8_estimation", "method": "LM(sum)", "m": m, "us": t_lm * 1e6})
        rows.append({"figure": "fig8_estimation", "method": "QSketch(MLE)", "m": m, "us": t_q * 1e6})
        common.csv_row(f"estimation/m{m}/LM", t_lm * 1e6, "O(m) sum")
        common.csv_row(f"estimation/m{m}/QSketch-MLE", t_q * 1e6, "O(m) bincount + O(2^b) newton")
    # Dyn anytime estimate: read a scalar.
    rows.append({"figure": "fig8_estimation", "method": "QSketch-Dyn(running)", "m": 0, "us": 0.0})
    common.csv_row("estimation/any/QSketch-Dyn", 0.0, "O(0): running martingale scalar")
    return rows


def run(quick=True):
    rows = run_update_throughput(quick) + run_estimation_time(quick)
    common.save("throughput", rows)
    return rows
