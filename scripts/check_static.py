#!/usr/bin/env python
"""qlint CLI — run the repo's static-analysis suite (DESIGN.md §9).

One runner replaces the former trio (docstring audit, qsketch_mle layering
grep, bench-schema check) and adds the contract rules: layering,
int8-overflow, donation-safety, jit-purity, kernel-contract.

Usage:
    python scripts/check_static.py                     # full repo
    python scripts/check_static.py --changed-only      # git-changed files
    python scripts/check_static.py src/repro/core/dyn_array.py
    python scripts/check_static.py --rules layering,int8-overflow
    python scripts/check_static.py --list-rules
    python scripts/check_static.py --update-baseline   # grandfather new findings
    python scripts/check_static.py --prune-baseline    # drop stale entries

Writes a JSON report (default ``experiments/analysis/report.json``) and
exits non-zero on any finding that is neither baselined
(``scripts/qlint_baseline.json``) nor inline-suppressed
(``# qlint: disable=<rule>``). Wired into ``scripts/test.sh --tier2``.
"""

from __future__ import annotations

import argparse
import json
import os
import sys

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
sys.path.insert(0, os.path.join(REPO, "src"))

from repro.analysis import all_rules, run_qlint  # noqa: E402
from repro.analysis.baseline import Baseline  # noqa: E402
from repro.analysis.runner import DEFAULT_BASELINE  # noqa: E402


def main(argv: list[str] | None = None) -> int:
    """Parse args, run qlint, print the summary, return the exit code."""
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("paths", nargs="*", help="repo-relative files to report on")
    ap.add_argument("--root", default=REPO, help="repo root (default: this repo)")
    ap.add_argument("--changed-only", action="store_true",
                    help="report only on git-changed files")
    ap.add_argument("--rules", default=None,
                    help="comma-separated rule subset (default: all)")
    ap.add_argument("--json", dest="json_out",
                    default=os.path.join("experiments", "analysis", "report.json"),
                    help="report path relative to root ('' disables)")
    ap.add_argument("--baseline", default=DEFAULT_BASELINE,
                    help="baseline file relative to root ('' disables)")
    ap.add_argument("--list-rules", action="store_true")
    ap.add_argument("--update-baseline", action="store_true",
                    help="add every new finding to the baseline (justify after!)")
    ap.add_argument("--prune-baseline", action="store_true",
                    help="drop baseline entries no finding matches anymore "
                         "(full runs only — a partial run cannot tell stale "
                         "from unexercised)")
    args = ap.parse_args(argv)

    if args.list_rules:
        for rule in all_rules():
            print(f"{rule.name:16s} {rule.description}")
        return 0

    report = run_qlint(
        args.root,
        rule_subset=args.rules.split(",") if args.rules else None,
        selected=args.paths or None,
        changed_only=args.changed_only,
        baseline_path=args.baseline or None,
    )

    if args.json_out:
        out_path = os.path.join(args.root, args.json_out)
        os.makedirs(os.path.dirname(out_path), exist_ok=True)
        with open(out_path, "w") as f:
            json.dump(report, f, indent=2)
            f.write("\n")

    if args.update_baseline or args.prune_baseline:
        base = Baseline(os.path.join(args.root, args.baseline))
        changed = False
        if args.update_baseline:
            for row in report["findings"]:
                if not row["baselined"]:
                    base.entries[row["key"]] = "TODO: justify (added by --update-baseline)"
                    changed = True
        if args.prune_baseline:
            for key in report["stale_baseline_keys"]:
                base.entries.pop(key, None)
                changed = True
        if changed:
            base.save()
            print(f"qlint: baseline updated ({len(base.entries)} entries)")
        return 0

    counts = report["counts"]
    new_rows = [r for r in report["findings"] if not r["baselined"]]
    for row in new_rows:
        print(f"{row['path']}:{row['line']}: [{row['rule']}] {row['message']}")
    per_rule = " ".join(f"{k}={v}" for k, v in counts["per_rule"].items())
    status = "OK" if report["ok"] else "FAIL"
    print(
        f"qlint: {status} — {counts['new']} new, {counts['baselined']} "
        f"baselined/suppressed over {report['files_selected']} files "
        f"({report['elapsed_s']}s; {per_rule})"
    )
    if report["stale_baseline_keys"]:
        print(
            f"qlint: note — {len(report['stale_baseline_keys'])} stale "
            "baseline entr(ies); run --prune-baseline"
        )
    return 0 if report["ok"] else 1


if __name__ == "__main__":
    sys.exit(main())
