"""EXPERIMENTS.md section generators (dry-run, roofline, repro tables).

    PYTHONPATH=src python -m repro.roofline.report   # prints all sections

The §Perf iteration log is hand-written (it narrates hypotheses); everything
tabular regenerates from experiments/{dryrun,bench}/*.json so the report
can never drift from the artifacts.
"""

from __future__ import annotations

import glob
import json
import os

from . import hw

DRYRUN_DIR = "experiments/dryrun"
BENCH_DIR = "experiments/bench"

ARCH_ORDER = [
    "jamba-1.5-large-398b", "llava-next-34b", "minitron-8b", "qwen3-8b",
    "gemma3-27b", "h2o-danube-1.8b", "whisper-large-v3", "kimi-k2-1t-a32b",
    "arctic-480b", "mamba2-370m",
]
SHAPE_ORDER = ["train_4k", "prefill_32k", "decode_32k", "long_500k"]


def _load(tag: str):
    recs = {}
    for path in glob.glob(os.path.join(DRYRUN_DIR, f"*{tag}.json")):
        r = json.load(open(path))
        recs[(r["arch"], r["shape"])] = r
    return recs


def _gib(x):
    return x / 2**30


def dryrun_table(tag="_singlepod") -> str:
    recs = _load(tag)
    mesh_lbl = "16x16 (256 chips)" if tag == "_singlepod" else "2x16x16 (512 chips)"
    out = [
        f"**Mesh {mesh_lbl}** — every cell `.lower().compile()`d; bytes are per-device "
        "from `memory_analysis()`; FLOPs/collectives are loop-aware per-device "
        "(`roofline/hlo_stats.py`).",
        "",
        "| arch | shape | status | args GiB | temp GiB | peak GiB | fits 16GiB | dot FLOPs/dev | coll bytes/dev | dominant coll |",
        "|---|---|---|---:|---:|---:|---|---:|---:|---|",
    ]
    for arch in ARCH_ORDER:
        for shape in SHAPE_ORDER:
            r = recs.get((arch, shape))
            if r is None:
                continue
            if r["status"] == "skip":
                out.append(f"| {arch} | {shape} | SKIP: {r['skip_reason']} | | | | | | | |")
                continue
            if r["status"] != "ok":
                out.append(f"| {arch} | {shape} | **FAIL** | | | | | | | |")
                continue
            pd = r["per_device"]
            peak = r["hbm_fit"]["peak_bytes_est"]
            fits = "yes" if peak <= hw.CHIP_HBM_BYTES else f"NO ({_gib(peak):.0f} GiB)"
            dom = max(pd["collective_by_op"], key=pd["collective_by_op"].get) if pd["collective_by_op"] else "-"
            out.append(
                f"| {arch} | {shape} | ok | {_gib(pd['argument_bytes']):.2f} | "
                f"{_gib(pd['temp_bytes']):.2f} | {_gib(peak):.2f} | {fits} | "
                f"{pd['flops']:.2e} | {pd['collective_bytes']:.2e} | {dom} |"
            )
    return "\n".join(out)


def roofline_table(tag="_singlepod") -> str:
    recs = _load(tag)
    out = [
        "All terms in SECONDS per step (per-device quantity / per-chip peak: "
        f"{hw.PEAK_FLOPS_BF16/1e12:.0f} TF/s bf16, {hw.HBM_BW/1e9:.0f} GB/s HBM, "
        f"{hw.ICI_LINK_BW/1e9:.0f} GB/s link). useful = MODEL_FLOPS / HLO_FLOPs "
        "(6·N_active·D train, 2·N_active·D inference). frac-of-roofline = "
        "compute_term / max(all terms).",
        "",
        "| arch | shape | compute s | memory s | collective s | bottleneck | useful | frac |",
        "|---|---|---:|---:|---:|---|---:|---:|",
    ]
    for arch in ARCH_ORDER:
        for shape in SHAPE_ORDER:
            r = recs.get((arch, shape))
            if r is None or r["status"] != "ok":
                continue
            t = r["roofline"]
            tmax = max(t.values())
            frac = t["compute_s"] / tmax if tmax > 0 else 0.0
            out.append(
                f"| {arch} | {shape} | {t['compute_s']:.3e} | {t['memory_s']:.3e} | "
                f"{t['collective_s']:.3e} | {r['bottleneck']} | "
                f"{r['useful_flops_ratio']:.2f} | {frac:.3f} |"
            )
    return "\n".join(out)


def repro_tables() -> str:
    out = []
    acc_path = os.path.join(BENCH_DIR, "accuracy.json")
    if os.path.exists(acc_path):
        rows = json.load(open(acc_path))
        out += ["**RRMSE vs m (gamma weights, paper Figs. 2/3 analogue):**", "",
                "| m | " + " | ".join(["LM", "FastGM", "FastExpSketch", "QSketch", "QSketch-Dyn"]) + " |",
                "|---|---|---|---|---|---|"]
        ms = sorted({r["m"] for r in rows if r["figure"] == "fig2_3_rrmse_vs_m"})
        for m in ms:
            vals = []
            for meth in ["LM", "FastGM", "FastExpSketch", "QSketch", "QSketch-Dyn"]:
                r = [x for x in rows if x["figure"] == "fig2_3_rrmse_vs_m" and x["m"] == m
                     and x["dist"] == "gamma" and x["method"] == meth]
                vals.append(f"{r[0]['rrmse']:.4f}" if r else "-")
            out.append(f"| {m} | " + " | ".join(vals) + " |")
        out.append("")
    th_path = os.path.join(BENCH_DIR, "throughput.json")
    if os.path.exists(th_path):
        rows = json.load(open(th_path))
        out += ["**Update throughput, Mops (CPU-JAX; ordering/scaling are the claims):**", ""]
        ms = sorted({r["m"] for r in rows if r["figure"] == "fig6_7_throughput"})
        methods = []
        for r in rows:
            if r["figure"] == "fig6_7_throughput" and r["method"] not in methods:
                methods.append(r["method"])
        out += ["| m | " + " | ".join(methods) + " |", "|" + "---|" * (len(methods) + 1)]
        for m in ms:
            vals = []
            for meth in methods:
                r = [x for x in rows if x["figure"] == "fig6_7_throughput" and x["m"] == m and x["method"] == meth]
                vals.append(f"{r[0]['mops']:.2f}" if r else "-")
            out.append(f"| {m} | " + " | ".join(vals) + " |")
        out.append("")
    return "\n".join(out)


def main():
    print("## §Dry-run (single-pod)\n")
    print(dryrun_table("_singlepod"))
    print("\n## §Dry-run (multi-pod)\n")
    print(dryrun_table("_multipod"))
    print("\n## §Roofline (single-pod)\n")
    print(roofline_table("_singlepod"))
    print("\n## §Repro\n")
    print(repro_tables())


if __name__ == "__main__":
    main()
