"""Quickstart: weighted cardinality estimation with every sketch in the library.

    PYTHONPATH=src python examples/quickstart.py
"""

import jax.numpy as jnp
import numpy as np

from repro.core import METHODS, SketchConfig
from repro.data import synthetic


def main():
    # A gamma-weighted stream with heavy Zipf repeats: 40k occurrences of
    # 8k distinct elements. True weighted cardinality = sum of distinct
    # elements' weights.
    ids, weights, true_c = synthetic.with_repeats("gamma", 8_000, 40_000, seed=0)
    print(f"stream: {len(ids)} occurrences, true weighted cardinality C = {true_c:,.1f}\n")

    cfg = SketchConfig(m=1024, b=8, seed=42)
    print(f"{'method':<16} {'estimate':>14} {'rel.err':>9} {'memory':>10}")
    for name, meth in METHODS.items():
        state = meth["init"](cfg)
        # Stream in batches, as a real pipeline would.
        for i in range(0, len(ids), 8192):
            state = meth["update"](
                cfg, state, jnp.asarray(ids[i : i + 8192]), jnp.asarray(weights[i : i + 8192])
            )
        est = float(meth["estimate"](cfg, state))
        bits = meth["register_bits"] or cfg.b
        mem = cfg.m * bits / 8
        print(f"{name:<16} {est:>14,.1f} {abs(est-true_c)/true_c:>8.2%} {mem:>8.0f} B")

    print("\nQSketch uses 8-bit registers (b=8): 1/4 the memory of the f32")
    print("baselines here, 1/8 of the paper's 64-bit baseline registers.")

    # Merging: sketches of two sub-streams combine losslessly.
    from repro.core import qsketch

    half = len(ids) // 2
    a = qsketch.update(cfg, qsketch.init(cfg), jnp.asarray(ids[:half]), jnp.asarray(weights[:half]))
    b = qsketch.update(cfg, qsketch.init(cfg), jnp.asarray(ids[half:]), jnp.asarray(weights[half:]))
    merged = qsketch.merge(a, b)
    print(f"\nmerge(first half, second half) estimate: {float(qsketch.estimate(cfg, merged)):,.1f}")


if __name__ == "__main__":
    main()
