"""Data pipeline: synthetic weighted streams + LM token batches."""

from . import synthetic, tokens

__all__ = ["synthetic", "tokens"]
